//! Small dense linear algebra: matrix products, matrix exponentials
//! (scaling-and-squaring), Fréchet derivatives of `exp` (Van Loan block
//! trick), QR-based random orthogonal matrices, and SO(3)/so(3) closed forms.
//!
//! Everything is row-major `&[f64]` with explicit dimensions. The hot
//! kernels ([`matmul`], [`matvec`], [`expm_into`], [`expm_frechet_into`])
//! are register-blocked/unrolled and write into caller-owned buffers; the
//! `expm*_into` family draws its Padé/Taylor scratch panels from a
//! [`StepWorkspace`] so a warm call performs zero heap allocations. The
//! original allocating signatures ([`expm`], [`expm_frechet`],
//! [`transpose`], …) survive as thin wrappers for cold call sites.
//!
//! Under the `simd` cargo feature each hot kernel ([`dot`],
//! [`dot_strided`], [`matvec`], [`matvec_t`], [`matmul`],
//! [`matmul_lanes`]) is a thin runtime dispatcher: when [`simd_enabled`]
//! (the `EES_SIMD` / `[exec] simd` knob) it routes to the explicit-width
//! kernels in the `simd` submodule, otherwise to the `*_scalar` reference
//! kernels, whose float-op order defines the crate's bitwise determinism
//! contract. Without the feature the dispatchers compile straight to the
//! scalar kernels (zero overhead, knob inert). See
//! `docs/ARCHITECTURE.md` §SIMD kernels & the determinism contract.

use crate::memory::StepWorkspace;

#[cfg(feature = "simd")]
pub mod simd;

#[cfg(feature = "simd")]
static SIMD_MODE: std::sync::atomic::AtomicU8 = std::sync::atomic::AtomicU8::new(0);

/// Whether the hot kernels currently dispatch to their SIMD variants.
/// Resolution: a process-wide [`set_simd`] override when one was made,
/// otherwise [`crate::config::default_simd`] (the `EES_SIMD` env var).
/// A relaxed atomic load — cheap enough for per-call checks, and worker
/// threads of the batch engine observe the same process-wide state.
#[cfg(feature = "simd")]
#[inline]
pub fn simd_enabled() -> bool {
    match SIMD_MODE.load(std::sync::atomic::Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => crate::config::default_simd(),
    }
}

/// Without the `simd` feature the SIMD arm does not exist: compile-time
/// `false`, so the dispatchers fold away entirely.
#[cfg(not(feature = "simd"))]
#[inline]
pub fn simd_enabled() -> bool {
    false
}

/// Opaque snapshot of the SIMD dispatch knob — what [`set_simd`] returns
/// and [`restore_simd`] accepts, so a caller can put the knob back to
/// whatever it was (including the "no override yet, follow `EES_SIMD`"
/// default, which a plain `set_simd(bool)` round-trip cannot express).
#[derive(Clone, Copy, Debug)]
pub struct SimdMode(#[cfg(feature = "simd")] u8);

/// Process-wide override of the SIMD dispatch knob (the scenario registry
/// applies `[exec] simd` through this once at setup; tests/benches should
/// prefer the restoring [`simd_override`] guard). Overrides the `EES_SIMD`
/// default until the next call and returns the previous [`SimdMode`] for
/// [`restore_simd`]. Note the portable SIMD kernels are bitwise-identical
/// to the scalar ones (they pack, never reassociate — see the `simd`
/// module docs), so on builds without the AVX2+FMA specialisation this
/// toggle is numerically invisible.
#[cfg(feature = "simd")]
pub fn set_simd(on: bool) -> SimdMode {
    SimdMode(SIMD_MODE.swap(
        if on { 2 } else { 1 },
        std::sync::atomic::Ordering::Relaxed,
    ))
}

/// Without the `simd` feature the knob is inert (accepted for source
/// compatibility so callers need no `cfg`).
#[cfg(not(feature = "simd"))]
pub fn set_simd(_on: bool) -> SimdMode {
    SimdMode()
}

/// Restore the knob to a [`SimdMode`] previously returned by [`set_simd`]
/// — including the un-overridden default that re-reads `EES_SIMD`.
#[cfg(feature = "simd")]
pub fn restore_simd(prev: SimdMode) {
    SIMD_MODE.store(prev.0, std::sync::atomic::Ordering::Relaxed);
}

/// Inert without the `simd` feature.
#[cfg(not(feature = "simd"))]
pub fn restore_simd(_prev: SimdMode) {}

/// RAII form of [`set_simd`]: flips the knob and restores the previous
/// [`SimdMode`] on drop (panic included). This is the toggle tests MUST
/// use — a bare `set_simd(false)` at the end of a test latches a scalar
/// override for the rest of the process, silently defeating an
/// `EES_SIMD=1` suite run for every test that follows.
#[must_use = "dropping the guard immediately restores the previous mode"]
pub struct SimdGuard {
    prev: SimdMode,
}

/// Flip the SIMD dispatch knob for the lifetime of the returned
/// [`SimdGuard`]; the previous mode (override or `EES_SIMD` default)
/// comes back when the guard drops.
pub fn simd_override(on: bool) -> SimdGuard {
    SimdGuard {
        prev: set_simd(on),
    }
}

impl Drop for SimdGuard {
    fn drop(&mut self) {
        restore_simd(self.prev);
    }
}

/// Dot product — the float-op-order definition every GEMV/GEMM path in
/// the crate shares. Dispatches to the SIMD kernel when [`simd_enabled`],
/// else to the scalar reference [`dot_scalar`].
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    #[cfg(feature = "simd")]
    {
        if simd_enabled() {
            return simd::dot(a, b);
        }
    }
    dot_scalar(a, b)
}

/// 4-way unrolled dot product — independent accumulators so LLVM can
/// vectorise the reduction (a single serial accumulator pins the f64
/// addition order and blocks SIMD). Shared by [`matvec`] and the MLP
/// forward in [`crate::nn`]. This is the scalar reference kernel whose
/// accumulation order ((s0+s1)+(s2+s3) over 4-chunks, sequential tail)
/// defines the bitwise determinism contract.
#[inline]
pub fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = 4 * c;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        acc += a[i] * b[i];
    }
    acc
}

/// C = A·B for row-major (m×k)·(k×n). Dispatches to the SIMD kernel when
/// [`simd_enabled`], else to the scalar reference [`matmul_scalar`].
pub fn matmul(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    #[cfg(feature = "simd")]
    {
        if simd_enabled() {
            return simd::matmul(a, b, c, m, k, n);
        }
    }
    matmul_scalar(a, b, c, m, k, n);
}

/// C = A·B for row-major (m×k)·(k×n), register-blocked over 4 rows of B so
/// each pass streams four B-rows against one resident C-row (4× less C
/// traffic than the rank-1 update loop, and an unrolled FMA body). Scalar
/// reference kernel.
pub fn matmul_scalar(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        crow.fill(0.0);
        let mut p = 0;
        while p + 4 <= k {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            let b0 = &b[p * n..(p + 1) * n];
            let b1 = &b[(p + 1) * n..(p + 2) * n];
            let b2 = &b[(p + 2) * n..(p + 3) * n];
            let b3 = &b[(p + 3) * n..(p + 4) * n];
            for (j, cj) in crow.iter_mut().enumerate() {
                *cj += (a0 * b0[j] + a1 * b1[j]) + (a2 * b2[j] + a3 * b3[j]);
            }
            p += 4;
        }
        while p < k {
            let ap = arow[p];
            if ap != 0.0 {
                let brow = &b[p * n..(p + 1) * n];
                for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                    *cj += ap * bj;
                }
            }
            p += 1;
        }
    }
}

/// y = A·x for row-major (m×n)·(n). Dispatches to the SIMD kernel when
/// [`simd_enabled`], else to the scalar reference [`matvec_scalar`].
pub fn matvec(a: &[f64], x: &[f64], y: &mut [f64], m: usize, n: usize) {
    #[cfg(feature = "simd")]
    {
        if simd_enabled() {
            return simd::matvec(a, x, y, m, n);
        }
    }
    matvec_scalar(a, x, y, m, n);
}

/// y = A·x for row-major (m×n)·(n), each row reduced with the unrolled
/// [`dot_scalar`] kernel. Scalar reference kernel.
pub fn matvec_scalar(a: &[f64], x: &[f64], y: &mut [f64], m: usize, n: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(y.len(), m);
    for (yi, row) in y.iter_mut().zip(a.chunks_exact(n)).take(m) {
        *yi = dot_scalar(row, x);
    }
}

/// Strided companion of [`dot`]: reduces `Σ_i a[offset + i*stride] * x[i]`
/// in [`dot`]'s accumulation order. Dispatches to the SIMD kernel when
/// [`simd_enabled`], else to the scalar reference [`dot_strided_scalar`].
#[inline]
pub fn dot_strided(a: &[f64], offset: usize, stride: usize, x: &[f64]) -> f64 {
    #[cfg(feature = "simd")]
    {
        if simd_enabled() {
            return simd::dot_strided(a, offset, stride, x);
        }
    }
    dot_strided_scalar(a, offset, stride, x)
}

/// Strided scalar reference kernel: reduces `Σ_i a[offset + i*stride] *
/// x[i]` with exactly [`dot_scalar`]'s accumulation order (four
/// independent accumulators over 4-chunks, combined as `(s0+s1)+(s2+s3)`,
/// then a sequential tail). This is what lets every GEMV/GEMM path in the
/// crate — row-major ([`matvec`]), transposed ([`matvec_t`]) and
/// lane-blocked ([`matmul_lanes`]) — share ONE float-op-order definition,
/// so their outputs are bitwise-comparable wherever they reduce the same
/// products.
#[inline]
pub fn dot_strided_scalar(a: &[f64], offset: usize, stride: usize, x: &[f64]) -> f64 {
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = 4 * c;
        s0 += a[offset + i * stride] * x[i];
        s1 += a[offset + (i + 1) * stride] * x[i + 1];
        s2 += a[offset + (i + 2) * stride] * x[i + 2];
        s3 += a[offset + (i + 3) * stride] * x[i + 3];
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        acc += a[offset + i * stride] * x[i];
    }
    acc
}

/// y = Aᵀ·x for row-major A (m×n), x length m, y length n. Dispatches to
/// the SIMD kernel when [`simd_enabled`], else to the scalar reference
/// [`matvec_t_scalar`].
pub fn matvec_t(a: &[f64], x: &[f64], y: &mut [f64], m: usize, n: usize) {
    #[cfg(feature = "simd")]
    {
        if simd_enabled() {
            return simd::matvec_t(a, x, y, m, n);
        }
    }
    matvec_t_scalar(a, x, y, m, n);
}

/// y = Aᵀ·x scalar reference kernel: each output is reduced with
/// [`dot_strided_scalar`] — the same accumulation order as [`dot`] /
/// [`matvec`], so transposed and untransposed GEMV agree bitwise on the
/// same products (one float-op-order definition for every GEMV path).
pub fn matvec_t_scalar(a: &[f64], x: &[f64], y: &mut [f64], m: usize, n: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), m);
    for (j, yj) in y.iter_mut().enumerate().take(n) {
        *yj = dot_strided_scalar(a, j, n, x);
    }
}

/// Hard cap on the lane count of the lane-blocked kernels (the stage
/// accumulators live in fixed-size stack arrays). The batch engine clamps
/// its `EES_LANES` / `[exec] lanes` knob to this.
pub const MAX_LANES: usize = 16;

/// Lane-blocked GEMM for the structure-of-arrays batch hot path:
/// `out[i*lanes + l] = Σ_k a[i*k_dim + k] · x[k*lanes + l]`, where `x` and
/// `out` are lane-major blocks (component-major, `lanes` consecutive lane
/// values per component). Dispatches to the SIMD kernel when
/// [`simd_enabled`], else to the scalar reference [`matmul_lanes_scalar`].
pub fn matmul_lanes(a: &[f64], x: &[f64], out: &mut [f64], m: usize, k_dim: usize, lanes: usize) {
    #[cfg(feature = "simd")]
    {
        if simd_enabled() {
            return simd::matmul_lanes(a, x, out, m, k_dim, lanes);
        }
    }
    matmul_lanes_scalar(a, x, out, m, k_dim, lanes);
}

/// Scalar reference kernel of [`matmul_lanes`]. The reduction over `k`
/// runs in **exactly the order of [`dot`]** (four accumulators per lane
/// over 4-chunks, combined `(s0+s1)+(s2+s3)`, sequential tail), so column
/// `l` of the output is bitwise-identical to `dot(a_row, x_lane_l)` on
/// the gathered lane — the contract that makes lane-blocked stepping
/// invisible to the per-sample determinism suite.
pub fn matmul_lanes_scalar(
    a: &[f64],
    x: &[f64],
    out: &mut [f64],
    m: usize,
    k_dim: usize,
    lanes: usize,
) {
    assert!(lanes >= 1 && lanes <= MAX_LANES, "lanes {lanes} out of range");
    debug_assert_eq!(a.len(), m * k_dim);
    debug_assert_eq!(x.len(), k_dim * lanes);
    debug_assert_eq!(out.len(), m * lanes);
    let chunks = k_dim / 4;
    let mut s0 = [0.0f64; MAX_LANES];
    let mut s1 = [0.0f64; MAX_LANES];
    let mut s2 = [0.0f64; MAX_LANES];
    let mut s3 = [0.0f64; MAX_LANES];
    for i in 0..m {
        let row = &a[i * k_dim..(i + 1) * k_dim];
        s0[..lanes].fill(0.0);
        s1[..lanes].fill(0.0);
        s2[..lanes].fill(0.0);
        s3[..lanes].fill(0.0);
        for c in 0..chunks {
            let k = 4 * c;
            let (a0, a1, a2, a3) = (row[k], row[k + 1], row[k + 2], row[k + 3]);
            let x0 = &x[k * lanes..(k + 1) * lanes];
            let x1 = &x[(k + 1) * lanes..(k + 2) * lanes];
            let x2 = &x[(k + 2) * lanes..(k + 3) * lanes];
            let x3 = &x[(k + 3) * lanes..(k + 4) * lanes];
            for l in 0..lanes {
                s0[l] += a0 * x0[l];
                s1[l] += a1 * x1[l];
                s2[l] += a2 * x2[l];
                s3[l] += a3 * x3[l];
            }
        }
        let orow = &mut out[i * lanes..(i + 1) * lanes];
        for l in 0..lanes {
            orow[l] = (s0[l] + s1[l]) + (s2[l] + s3[l]);
        }
        for k in 4 * chunks..k_dim {
            let ak = row[k];
            let xk = &x[k * lanes..(k + 1) * lanes];
            for (o, xv) in orow.iter_mut().zip(xk.iter()) {
                *o += ak * xv;
            }
        }
    }
}

/// Gather lane `lane` of a lane-major block (`dst.len()` components ×
/// `lanes`) into a contiguous per-sample vector. Width-unrolled (4
/// components per iteration, strided loads hoisted to one base index) —
/// pure copies, so bitwise-trivially equal to the plain loop, which
/// survives as the tail.
#[inline]
pub fn lane_gather(block: &[f64], lane: usize, lanes: usize, dst: &mut [f64]) {
    debug_assert!(lane < lanes);
    debug_assert_eq!(block.len(), dst.len() * lanes);
    let n = dst.len();
    let chunks = n / 4;
    for c in 0..chunks {
        let i = 4 * c;
        let base = i * lanes + lane;
        dst[i] = block[base];
        dst[i + 1] = block[base + lanes];
        dst[i + 2] = block[base + 2 * lanes];
        dst[i + 3] = block[base + 3 * lanes];
    }
    for i in 4 * chunks..n {
        dst[i] = block[i * lanes + lane];
    }
}

/// Scatter a contiguous per-sample vector into lane `lane` of a lane-major
/// block (`src.len()` components × `lanes`) — the inverse of
/// [`lane_gather`], with the same width-unrolled body.
#[inline]
pub fn lane_scatter(src: &[f64], lane: usize, lanes: usize, block: &mut [f64]) {
    debug_assert!(lane < lanes);
    debug_assert_eq!(block.len(), src.len() * lanes);
    let n = src.len();
    let chunks = n / 4;
    for c in 0..chunks {
        let i = 4 * c;
        let base = i * lanes + lane;
        block[base] = src[i];
        block[base + lanes] = src[i + 1];
        block[base + 2 * lanes] = src[i + 2];
        block[base + 3 * lanes] = src[i + 3];
    }
    for i in 4 * chunks..n {
        block[i * lanes + lane] = src[i];
    }
}

/// Transpose (m×n) into a caller-owned (n×m) buffer.
pub fn transpose_into(a: &[f64], out: &mut [f64], m: usize, n: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(out.len(), n * m);
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = a[i * n + j];
        }
    }
}

/// Transpose (m×n) → (n×m) (allocating wrapper over [`transpose_into`]).
pub fn transpose(a: &[f64], m: usize, n: usize) -> Vec<f64> {
    let mut t = vec![0.0; n * m];
    transpose_into(a, &mut t, m, n);
    t
}

/// Overwrite a caller-owned n×n buffer with the identity.
pub fn eye_into(out: &mut [f64], n: usize) {
    debug_assert_eq!(out.len(), n * n);
    out.fill(0.0);
    for i in 0..n {
        out[i * n + i] = 1.0;
    }
}

/// n×n identity.
pub fn eye(n: usize) -> Vec<f64> {
    let mut a = vec![0.0; n * n];
    eye_into(&mut a, n);
    a
}

/// Max-abs norm, 4-way unrolled (it sits on the [`expm_into`] hot path —
/// one call per exponential for the scaling power). `max` is associative
/// and commutative on the non-NaN inputs this crate produces, so the
/// unrolled combine is bitwise-equal to the serial fold (pinned in the
/// tests below).
pub fn norm_inf(a: &[f64]) -> f64 {
    let chunks = a.len() / 4;
    let (mut m0, mut m1, mut m2, mut m3) = (0.0f64, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = 4 * c;
        m0 = m0.max(a[i].abs());
        m1 = m1.max(a[i + 1].abs());
        m2 = m2.max(a[i + 2].abs());
        m3 = m3.max(a[i + 3].abs());
    }
    let mut m = (m0.max(m1)).max(m2.max(m3));
    for x in &a[4 * chunks..] {
        m = m.max(x.abs());
    }
    m
}

/// Frobenius / ℓ2 norm — the serial reference reduction, deliberately
/// independent of the SIMD dispatch knob. Reassociating this onto the
/// 4-accumulator [`dot`] kernel would bitwise-change everything
/// downstream (notably the `Sphere` retraction normalisation on the
/// stepping path) — on the default path versus the pre-SIMD releases,
/// and between knob states on the portable SIMD arm, breaking the
/// "portable `EES_SIMD=1` is bitwise-identical to scalar" contract. Hot
/// call sites that already live under the SIMD tolerance contract can
/// use [`norm2_dot`] instead.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    a.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// ℓ2 norm reduced through the shared [`dot`] kernel — one
/// float-op-order definition with every GEMV/GEMM path, including the
/// SIMD dispatch. Reassociates relative to [`norm2`]: only for call
/// sites that don't sit under a serial-`norm2` bitwise pin.
#[inline]
pub fn norm2_dot(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// True iff the 3×3 row-major matrix is exactly skew-symmetric — the shape
/// every 𝔰𝔬(3) hat map produces, detected by exact comparison so the fast
/// path never fires on merely-close matrices.
#[inline]
fn is_skew3(a: &[f64]) -> bool {
    a[0] == 0.0
        && a[4] == 0.0
        && a[8] == 0.0
        && a[1] == -a[3]
        && a[2] == -a[6]
        && a[5] == -a[7]
}

/// Per-lane [`is_skew3`] on a lane-major block of 3×3 matrices: entry
/// (i, j) of lane `l` lives at `a[(i * 3 + j) * lanes + l]`.
#[inline]
fn is_skew3_lane(a: &[f64], l: usize, lanes: usize) -> bool {
    let g = |i: usize| a[i * lanes + l];
    g(0) == 0.0
        && g(4) == 0.0
        && g(8) == 0.0
        && g(1) == -g(3)
        && g(2) == -g(6)
        && g(5) == -g(7)
}

/// Lane-blocked matrix exponential: `a` and `out` are lane-major blocks of
/// `lanes` independent n×n matrices (entry (i, j) of lane `l` at
/// `[(i*n + j) * lanes + l]`), and lane `l` of `out` is **bitwise-equal**
/// to [`expm_into`] on the gathered lane. When every lane is exactly skew
/// 3×3 — the dominant case on SO(3)/S² — all lanes take the Rodrigues
/// closed form straight off the block with no gather. Otherwise each
/// lane's scaling power depends on its own norm, so the Taylor recurrence
/// cannot fuse across lanes without changing the float-op order: the
/// general path gathers each lane into one contiguous panel pair checked
/// out of `ws` and runs the scalar core per lane (warm calls still
/// allocate nothing).
pub fn expm_lanes_into(a: &[f64], out: &mut [f64], n: usize, lanes: usize, ws: &mut StepWorkspace) {
    assert!(lanes >= 1 && lanes <= MAX_LANES, "lanes {lanes} out of range");
    debug_assert_eq!(a.len(), n * n * lanes);
    debug_assert_eq!(out.len(), n * n * lanes);
    if lanes == 1 {
        expm_into(a, out, n, ws);
        return;
    }
    if n == 3 && (0..lanes).all(|l| is_skew3_lane(a, l, lanes)) {
        for l in 0..lanes {
            let w = [a[7 * lanes + l], a[2 * lanes + l], a[3 * lanes + l]];
            let e = so3_exp(&w);
            for (i, ei) in e.iter().enumerate() {
                out[i * lanes + l] = *ei;
            }
        }
        return;
    }
    let mut panel = ws.take(2 * n * n);
    {
        let (m, e) = panel.split_at_mut(n * n);
        for l in 0..lanes {
            lane_gather(a, l, lanes, m);
            expm_into(m, e, n, ws);
            lane_scatter(e, l, lanes, out);
        }
    }
    ws.put(panel);
}

/// Lane-blocked Fréchet derivative of the matrix exponential: all four
/// arguments are lane-major blocks of n×n matrices, and lane `l` of
/// (`ea`, `l_out`) is bitwise-equal to [`expm_frechet_into`] on the
/// gathered lane. The Van Loan 2n×2n panel never hits a fused fast path,
/// so this is the gather-per-lane layout adapter over the scalar core —
/// one contiguous `ws` checkout for all four per-lane panels.
pub fn expm_frechet_lanes_into(
    a: &[f64],
    e: &[f64],
    ea: &mut [f64],
    l_out: &mut [f64],
    n: usize,
    lanes: usize,
    ws: &mut StepWorkspace,
) {
    assert!(lanes >= 1 && lanes <= MAX_LANES, "lanes {lanes} out of range");
    debug_assert_eq!(a.len(), n * n * lanes);
    debug_assert_eq!(e.len(), n * n * lanes);
    debug_assert_eq!(ea.len(), n * n * lanes);
    debug_assert_eq!(l_out.len(), n * n * lanes);
    if lanes == 1 {
        expm_frechet_into(a, e, ea, l_out, n, ws);
        return;
    }
    let nn = n * n;
    let mut panel = ws.take(4 * nn);
    {
        let (ma, rest) = panel.split_at_mut(nn);
        let (me, rest) = rest.split_at_mut(nn);
        let (mea, ml) = rest.split_at_mut(nn);
        for l in 0..lanes {
            lane_gather(a, l, lanes, ma);
            lane_gather(e, l, lanes, me);
            expm_frechet_into(ma, me, mea, ml, n, ws);
            lane_scatter(mea, l, lanes, ea);
            lane_scatter(ml, l, lanes, l_out);
        }
    }
    ws.put(panel);
}

/// Matrix exponential of an n×n matrix into a caller-owned buffer, by
/// scaling-and-squaring on a degree-13 Taylor polynomial (accurate to
/// ~1e-14 for the modest norms arising in one integrator step, ‖A‖ ≲ a
/// few). Scratch panels come from `ws`, so a warm call never allocates.
/// Exactly skew 3×3 inputs short-circuit to the Rodrigues closed form
/// ([`so3_exp`]) — the dominant case on SO(3), S², and their products.
pub fn expm_into(a: &[f64], out: &mut [f64], n: usize, ws: &mut StepWorkspace) {
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(out.len(), n * n);
    if n == 3 && is_skew3(a) {
        out.copy_from_slice(&so3_exp(&[a[7], a[2], a[3]]));
        return;
    }
    let nrm = norm_inf(a);
    let mut s = 0u32;
    let mut scaled = ws.take_copy(a);
    if nrm > 0.5 {
        s = (nrm / 0.5).log2().ceil() as u32;
        let f = 0.5f64.powi(s as i32);
        for x in scaled.iter_mut() {
            *x *= f;
        }
    }
    // Taylor series: E = I + A + A²/2! + ... + A^13/13!
    let mut term = ws.take(n * n);
    let mut tmp = ws.take(n * n);
    eye_into(out, n);
    eye_into(&mut term, n);
    for k in 1..=13usize {
        matmul(&term, &scaled, &mut tmp, n, n, n);
        let inv = 1.0 / k as f64;
        for (t, &v) in term.iter_mut().zip(tmp.iter()) {
            *t = v * inv;
        }
        for (ei, ti) in out.iter_mut().zip(term.iter()) {
            *ei += ti;
        }
    }
    // Repeated squaring.
    for _ in 0..s {
        matmul(&*out, &*out, &mut tmp, n, n, n);
        out.copy_from_slice(&tmp);
    }
    ws.put(tmp);
    ws.put(term);
    ws.put(scaled);
}

/// Matrix exponential (allocating wrapper over [`expm_into`]).
pub fn expm(a: &[f64], n: usize) -> Vec<f64> {
    let mut ws = StepWorkspace::new();
    let mut e = vec![0.0; n * n];
    expm_into(a, &mut e, n, &mut ws);
    e
}

/// Fréchet derivative of the matrix exponential into caller-owned buffers:
/// writes exp(A) to `ea` and L_A(E) = d/dt exp(A + tE)|_{t=0} to `l`, via
/// Van Loan's block trick exp([[A, E], [0, A]]) = [[eᴬ, L],[0, eᴬ]]. The
/// 2n×2n panel lives in `ws`.
pub fn expm_frechet_into(
    a: &[f64],
    e: &[f64],
    ea: &mut [f64],
    l: &mut [f64],
    n: usize,
    ws: &mut StepWorkspace,
) {
    let n2 = 2 * n;
    let mut blk = ws.take(n2 * n2);
    for i in 0..n {
        for j in 0..n {
            blk[i * n2 + j] = a[i * n + j];
            blk[i * n2 + n + j] = e[i * n + j];
            blk[(n + i) * n2 + n + j] = a[i * n + j];
        }
    }
    let mut big = ws.take(n2 * n2);
    expm_into(&blk, &mut big, n2, ws);
    for i in 0..n {
        for j in 0..n {
            ea[i * n + j] = big[i * n2 + j];
            l[i * n + j] = big[i * n2 + n + j];
        }
    }
    ws.put(big);
    ws.put(blk);
}

/// Fréchet derivative (allocating wrapper over [`expm_frechet_into`]).
pub fn expm_frechet(a: &[f64], e: &[f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut ws = StepWorkspace::new();
    let mut ea = vec![0.0; n * n];
    let mut l = vec![0.0; n * n];
    expm_frechet_into(a, e, &mut ea, &mut l, n, &mut ws);
    (ea, l)
}

/// Adjoint of the Fréchet derivative into a caller-owned buffer: given a
/// cotangent W (n×n), writes L_A*(W) with ⟨W, L_A(E)⟩_F = ⟨L_A*(W), E⟩_F
/// for all E, via the identity L_A*(W) = L_{Aᵀ}(W).
pub fn expm_frechet_adjoint_into(
    a: &[f64],
    w: &[f64],
    out: &mut [f64],
    n: usize,
    ws: &mut StepWorkspace,
) {
    let mut at = ws.take(n * n);
    transpose_into(a, &mut at, n, n);
    let mut ea = ws.take(n * n);
    expm_frechet_into(&at, w, &mut ea, out, n, ws);
    ws.put(ea);
    ws.put(at);
}

/// Fréchet adjoint (allocating wrapper over [`expm_frechet_adjoint_into`]).
pub fn expm_frechet_adjoint(a: &[f64], w: &[f64], n: usize) -> Vec<f64> {
    let mut ws = StepWorkspace::new();
    let mut l = vec![0.0; n * n];
    expm_frechet_adjoint_into(a, w, &mut l, n, &mut ws);
    l
}

/// Random orthogonal matrix (Haar via QR of a Gaussian matrix with sign fix).
pub fn random_orthogonal(rng: &mut crate::rng::Pcg64, n: usize) -> Vec<f64> {
    let mut g = vec![0.0; n * n];
    rng.fill_normal(&mut g);
    // Gram-Schmidt on columns.
    let mut q = vec![0.0; n * n];
    for j in 0..n {
        let mut v: Vec<f64> = (0..n).map(|i| g[i * n + j]).collect();
        for k in 0..j {
            let dot: f64 = (0..n).map(|i| q[i * n + k] * v[i]).sum();
            for (i, vi) in v.iter_mut().enumerate() {
                *vi -= dot * q[i * n + k];
            }
        }
        let nrm = norm2(&v);
        for i in 0..n {
            q[i * n + j] = v[i] / nrm;
        }
    }
    q
}

// ---------------------------------------------------------------------------
// so(3) closed forms (Rodrigues).
// ---------------------------------------------------------------------------

/// Hat map: ω ∈ ℝ³ → 3×3 skew matrix.
pub fn so3_hat(w: &[f64]) -> [f64; 9] {
    [0.0, -w[2], w[1], w[2], 0.0, -w[0], -w[1], w[0], 0.0]
}

/// Inverse hat map.
pub fn so3_vee(m: &[f64]) -> [f64; 3] {
    [m[7], m[2], m[3]]
}

/// Rodrigues: exp of the skew matrix of ω.
pub fn so3_exp(w: &[f64]) -> [f64; 9] {
    let th2 = w[0] * w[0] + w[1] * w[1] + w[2] * w[2];
    let th = th2.sqrt();
    let (a, b) = if th < 1e-8 {
        (1.0 - th2 / 6.0, 0.5 - th2 / 24.0)
    } else {
        (th.sin() / th, (1.0 - th.cos()) / th2)
    };
    let k = so3_hat(w);
    let mut k2 = [0.0f64; 9];
    matmul(&k, &k, &mut k2, 3, 3, 3);
    let mut e = [0.0f64; 9];
    for i in 0..3 {
        e[i * 3 + i] = 1.0;
    }
    for i in 0..9 {
        e[i] += a * k[i] + b * k2[i];
    }
    e
}

/// 3×3 product convenience.
pub fn mat3mul(a: &[f64], b: &[f64]) -> [f64; 9] {
    let mut c = [0.0f64; 9];
    matmul(a, b, &mut c, 3, 3, 3);
    c
}

/// ‖RᵀR − I‖_∞: orthogonality defect of a 3×3 (or n×n) matrix.
pub fn orthogonality_defect(r: &[f64], n: usize) -> f64 {
    let rt = transpose(r, n, n);
    let mut p = vec![0.0; n * n];
    matmul(&rt, r, &mut p, n, n, n);
    for i in 0..n {
        p[i * n + i] -= 1.0;
    }
    norm_inf(&p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn matmul_small() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0; 4];
        matmul(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matvec_transpose_consistency() {
        // matvec_t now reduces through dot_strided — the same accumulation
        // order as dot/matvec — so Aᵀx agrees with matvec on the explicit
        // transpose BITWISE, not just to tolerance (one float-op-order
        // definition for every GEMV path).
        let mut rng = Pcg64::new(1);
        for (m, n) in [(4usize, 3usize), (9, 7), (16, 5)] {
            let mut a = vec![0.0; m * n];
            rng.fill_normal(&mut a);
            let x: Vec<f64> = (0..m).map(|i| (i as f64 + 1.0).sin()).collect();
            let mut y1 = vec![0.0; n];
            matvec_t(&a, &x, &mut y1, m, n);
            let at = transpose(&a, m, n);
            let mut y2 = vec![0.0; n];
            matvec(&at, &x, &mut y2, n, m);
            for (u, v) in y1.iter().zip(y2.iter()) {
                assert_eq!(u.to_bits(), v.to_bits(), "({m},{n})");
            }
        }
    }

    #[test]
    fn dot_strided_matches_dot() {
        let mut rng = Pcg64::new(23);
        for n in [1usize, 3, 4, 7, 8, 11, 32] {
            let mut a = vec![0.0; n];
            let mut x = vec![0.0; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut x);
            // Contiguous layout (stride 1, offset 0) must be exactly dot.
            assert_eq!(
                dot_strided(&a, 0, 1, &x).to_bits(),
                dot(&a, &x).to_bits(),
                "n={n}"
            );
            // A strided embedding of the same values gives the same bits.
            let stride = 3;
            let mut wide = vec![0.0; n * stride + 1];
            for (i, v) in a.iter().enumerate() {
                wide[1 + i * stride] = *v;
            }
            assert_eq!(
                dot_strided(&wide, 1, stride, &x).to_bits(),
                dot(&a, &x).to_bits(),
                "strided n={n}"
            );
        }
    }

    #[test]
    fn matmul_lanes_columns_match_per_lane_dot() {
        // The lane contract: column l of matmul_lanes equals dot(row, x_l)
        // on the gathered lane, bit for bit — for k both multiple-of-4 and
        // with a scalar tail, across lane counts including ragged ones.
        let mut rng = Pcg64::new(77);
        for (m, k) in [(5usize, 8usize), (3, 11), (7, 4), (2, 1)] {
            for lanes in [1usize, 2, 5, 8, MAX_LANES] {
                let mut a = vec![0.0; m * k];
                let mut x = vec![0.0; k * lanes];
                rng.fill_normal(&mut a);
                rng.fill_normal(&mut x);
                let mut out = vec![0.0; m * lanes];
                matmul_lanes(&a, &x, &mut out, m, k, lanes);
                let mut xl = vec![0.0; k];
                for l in 0..lanes {
                    lane_gather(&x, l, lanes, &mut xl);
                    for i in 0..m {
                        let want = dot(&a[i * k..(i + 1) * k], &xl);
                        assert_eq!(
                            out[i * lanes + l].to_bits(),
                            want.to_bits(),
                            "m={m} k={k} lanes={lanes} (i={i}, l={l})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lane_gather_scatter_round_trip() {
        let lanes = 3;
        let comps = 4;
        let mut block = vec![0.0; comps * lanes];
        let src: Vec<f64> = (0..comps).map(|c| c as f64 + 0.5).collect();
        lane_scatter(&src, 1, lanes, &mut block);
        let mut dst = vec![0.0; comps];
        lane_gather(&block, 1, lanes, &mut dst);
        assert_eq!(src, dst);
        // Other lanes untouched.
        lane_gather(&block, 0, lanes, &mut dst);
        assert!(dst.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn expm_diagonal() {
        let a = [1.0, 0.0, 0.0, 2.0];
        let e = expm(&a, 2);
        assert!((e[0] - 1f64.exp()).abs() < 1e-12);
        assert!((e[3] - 2f64.exp()).abs() < 1e-12);
        assert!(e[1].abs() < 1e-14 && e[2].abs() < 1e-14);
    }

    #[test]
    fn expm_rotation_2d() {
        // exp([[0,-t],[t,0]]) = rotation by t.
        let t = 0.7;
        let a = [0.0, -t, t, 0.0];
        let e = expm(&a, 2);
        assert!((e[0] - t.cos()).abs() < 1e-12);
        assert!((e[1] + t.sin()).abs() < 1e-12);
        assert!((e[2] - t.sin()).abs() < 1e-12);
        assert!((e[3] - t.cos()).abs() < 1e-12);
    }

    #[test]
    fn expm_large_norm_scaling() {
        // Known: exp(diag(10, -10)).
        let a = [10.0, 0.0, 0.0, -10.0];
        let e = expm(&a, 2);
        assert!((e[0] - 10f64.exp()).abs() / 10f64.exp() < 1e-10);
        assert!((e[3] - (-10f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn so3_exp_matches_expm() {
        let w = [0.3, -0.5, 0.2];
        let r1 = so3_exp(&w);
        let r2 = expm(&so3_hat(&w), 3);
        for i in 0..9 {
            assert!((r1[i] - r2[i]).abs() < 1e-12);
        }
        assert!(orthogonality_defect(&r1, 3) < 1e-12);
    }

    #[test]
    fn so3_hat_vee_round_trip() {
        let w = [0.1, 0.2, 0.3];
        let v = so3_vee(&so3_hat(&w));
        assert_eq!(v, [0.1, 0.2, 0.3]);
    }

    #[test]
    fn frechet_matches_finite_difference() {
        let mut rng = Pcg64::new(3);
        let n = 4;
        let mut a = vec![0.0; n * n];
        let mut e = vec![0.0; n * n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut e);
        for x in a.iter_mut() {
            *x *= 0.3;
        }
        let (ea, l) = expm_frechet(&a, &e, n);
        let ea2 = expm(&a, n);
        for (u, v) in ea.iter().zip(ea2.iter()) {
            assert!((u - v).abs() < 1e-11);
        }
        // Finite difference check.
        let eps = 1e-6;
        let ap: Vec<f64> = a.iter().zip(e.iter()).map(|(x, y)| x + eps * y).collect();
        let am: Vec<f64> = a.iter().zip(e.iter()).map(|(x, y)| x - eps * y).collect();
        let (ep, em) = (expm(&ap, n), expm(&am, n));
        for i in 0..n * n {
            let fd = (ep[i] - em[i]) / (2.0 * eps);
            assert!((fd - l[i]).abs() < 1e-7, "entry {i}: fd {fd} vs L {}", l[i]);
        }
    }

    #[test]
    fn frechet_adjoint_identity() {
        let mut rng = Pcg64::new(4);
        let n = 3;
        let mut a = vec![0.0; n * n];
        let mut e = vec![0.0; n * n];
        let mut w = vec![0.0; n * n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut e);
        rng.fill_normal(&mut w);
        for x in a.iter_mut() {
            *x *= 0.2;
        }
        let (_, l) = expm_frechet(&a, &e, n);
        let lstar = expm_frechet_adjoint(&a, &w, n);
        let lhs: f64 = w.iter().zip(l.iter()).map(|(x, y)| x * y).sum();
        let rhs: f64 = lstar.iter().zip(e.iter()).map(|(x, y)| x * y).sum();
        assert!((lhs - rhs).abs() < 1e-10, "{lhs} vs {rhs}");
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..11).map(|i| (i as f64 * 0.7).sin()).collect();
        let b: Vec<f64> = (0..11).map(|i| (i as f64 * 0.3).cos()).collect();
        let naive: f64 = a.iter().zip(b.iter()).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-13);
    }

    #[test]
    fn matmul_rectangular_odd_inner_dim() {
        // k = 5 exercises both the 4-blocked body and the scalar tail.
        let mut rng = Pcg64::new(17);
        let (m, k, n) = (3, 5, 4);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut b);
        let mut c = vec![0.0; m * n];
        matmul(&a, &b, &mut c, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let want: f64 = (0..k).map(|p| a[i * k + p] * b[p * n + j]).sum();
                assert!((c[i * n + j] - want).abs() < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn transpose_into_matches_transpose() {
        let a: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let t1 = transpose(&a, 3, 4);
        let mut t2 = vec![0.0; 12];
        transpose_into(&a, &mut t2, 3, 4);
        assert_eq!(t1, t2);
    }

    #[test]
    fn expm_into_reused_workspace_is_deterministic() {
        let mut rng = Pcg64::new(8);
        let mut ws = StepWorkspace::new();
        for n in [2usize, 3, 5] {
            let mut a = vec![0.0; n * n];
            rng.fill_normal(&mut a);
            for x in a.iter_mut() {
                *x *= 0.4;
            }
            let fresh = expm(&a, n);
            let mut reused = vec![0.0; n * n];
            expm_into(&a, &mut reused, n, &mut ws);
            for (u, v) in fresh.iter().zip(reused.iter()) {
                assert_eq!(u.to_bits(), v.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn expm_3x3_nonskew_takes_taylor_path() {
        // Upper-triangular input: exp is upper-triangular with exp(diag) on
        // the diagonal — and must not be misrouted to the Rodrigues path.
        let a = [0.3, 0.1, 0.0, 0.0, -0.2, 0.05, 0.0, 0.0, 0.1];
        let e = expm(&a, 3);
        assert!((e[0] - 0.3f64.exp()).abs() < 1e-12);
        assert!((e[4] - (-0.2f64).exp()).abs() < 1e-12);
        assert!((e[8] - 0.1f64.exp()).abs() < 1e-12);
        assert!(e[3].abs() < 1e-14 && e[6].abs() < 1e-14 && e[7].abs() < 1e-14);
    }

    #[test]
    fn expm_skew3_fast_path_is_rodrigues() {
        let w = [0.4, -0.7, 0.25];
        let e = expm(&so3_hat(&w), 3);
        let r = so3_exp(&w);
        for i in 0..9 {
            assert_eq!(e[i].to_bits(), r[i].to_bits(), "entry {i}");
        }
    }

    #[test]
    fn expm_lanes_matches_per_lane_expm() {
        // Both the all-skew3 Rodrigues block path and the general
        // gather-per-lane path must be bitwise-equal to scalar expm_into on
        // each gathered lane.
        let mut rng = Pcg64::new(41);
        let mut ws = StepWorkspace::new();
        for (n, skew) in [(3usize, true), (3, false), (4, false), (2, false)] {
            for lanes in [1usize, 2, 5, 8] {
                let mut a = vec![0.0; n * n * lanes];
                for l in 0..lanes {
                    let mut m = vec![0.0; n * n];
                    if skew {
                        let mut w = [0.0; 3];
                        rng.fill_normal(&mut w);
                        m.copy_from_slice(&so3_hat(&w));
                    } else {
                        rng.fill_normal(&mut m);
                        for x in m.iter_mut() {
                            *x *= 0.4;
                        }
                    }
                    lane_scatter(&m, l, lanes, &mut a);
                }
                let mut out = vec![0.0; n * n * lanes];
                expm_lanes_into(&a, &mut out, n, lanes, &mut ws);
                let mut m = vec![0.0; n * n];
                let mut e = vec![0.0; n * n];
                let mut got = vec![0.0; n * n];
                for l in 0..lanes {
                    lane_gather(&a, l, lanes, &mut m);
                    expm_into(&m, &mut e, n, &mut ws);
                    lane_gather(&out, l, lanes, &mut got);
                    for (u, v) in got.iter().zip(e.iter()) {
                        assert_eq!(u.to_bits(), v.to_bits(), "n={n} lanes={lanes} l={l}");
                    }
                }
            }
        }
    }

    #[test]
    fn expm_lanes_mixed_skewness_routes_per_lane() {
        // One skew lane next to a non-skew lane: each must follow the route
        // the scalar kernel would take for it alone.
        let mut ws = StepWorkspace::new();
        let lanes = 2;
        let n = 3;
        let skew = so3_hat(&[0.4, -0.7, 0.25]);
        let tri = [0.3, 0.1, 0.0, 0.0, -0.2, 0.05, 0.0, 0.0, 0.1];
        let mut a = vec![0.0; n * n * lanes];
        lane_scatter(&skew, 0, lanes, &mut a);
        lane_scatter(&tri, 1, lanes, &mut a);
        let mut out = vec![0.0; n * n * lanes];
        expm_lanes_into(&a, &mut out, n, lanes, &mut ws);
        let mut e = vec![0.0; n * n];
        let mut got = vec![0.0; n * n];
        for (l, src) in [(0usize, &skew[..]), (1, &tri[..])] {
            expm_into(src, &mut e, n, &mut ws);
            lane_gather(&out, l, lanes, &mut got);
            for (u, v) in got.iter().zip(e.iter()) {
                assert_eq!(u.to_bits(), v.to_bits(), "lane {l}");
            }
        }
    }

    #[test]
    fn expm_frechet_lanes_matches_per_lane() {
        let mut rng = Pcg64::new(42);
        let mut ws = StepWorkspace::new();
        for n in [2usize, 3, 4] {
            for lanes in [1usize, 3, 8] {
                let nn = n * n;
                let mut a = vec![0.0; nn * lanes];
                let mut e = vec![0.0; nn * lanes];
                rng.fill_normal(&mut a);
                rng.fill_normal(&mut e);
                for x in a.iter_mut() {
                    *x *= 0.3;
                }
                let mut ea = vec![0.0; nn * lanes];
                let mut lf = vec![0.0; nn * lanes];
                expm_frechet_lanes_into(&a, &e, &mut ea, &mut lf, n, lanes, &mut ws);
                let mut al = vec![0.0; nn];
                let mut el = vec![0.0; nn];
                let mut eal = vec![0.0; nn];
                let mut ll = vec![0.0; nn];
                let mut got = vec![0.0; nn];
                for l in 0..lanes {
                    lane_gather(&a, l, lanes, &mut al);
                    lane_gather(&e, l, lanes, &mut el);
                    expm_frechet_into(&al, &el, &mut eal, &mut ll, n, &mut ws);
                    lane_gather(&ea, l, lanes, &mut got);
                    for (u, v) in got.iter().zip(eal.iter()) {
                        assert_eq!(u.to_bits(), v.to_bits(), "ea n={n} lanes={lanes} l={l}");
                    }
                    lane_gather(&lf, l, lanes, &mut got);
                    for (u, v) in got.iter().zip(ll.iter()) {
                        assert_eq!(u.to_bits(), v.to_bits(), "L n={n} lanes={lanes} l={l}");
                    }
                }
            }
        }
    }

    #[test]
    fn random_orthogonal_is_orthogonal() {
        let mut rng = Pcg64::new(5);
        for n in [2, 5, 16] {
            let q = random_orthogonal(&mut rng, n);
            assert!(orthogonality_defect(&q, n) < 1e-10, "n={n}");
        }
    }

    #[test]
    fn norm_kernels_match_reference_loops() {
        let mut rng = Pcg64::new(91);
        for n in [1usize, 2, 3, 4, 7, 8, 13, 31, 64] {
            let mut a = vec![0.0; n];
            rng.fill_normal(&mut a);
            // norm2 is the untouched serial sum, independent of the SIMD
            // dispatch knob — pin it bitwise against the reference loop
            // under BOTH knob states (so an EES_SIMD=1 suite run proves
            // the knob cannot reach it).
            let serial: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
            for knob in [false, true] {
                let _mode = simd_override(knob);
                assert_eq!(norm2(&a).to_bits(), serial.to_bits(), "n={n} knob={knob}");
                // norm2_dot rides the shared dot kernel (and its SIMD
                // dispatch): bitwise the kernel identity, tolerance vs
                // the serial sum (it reassociates).
                assert_eq!(
                    norm2_dot(&a).to_bits(),
                    dot(&a, &a).sqrt().to_bits(),
                    "n={n} knob={knob}"
                );
                assert!(
                    (norm2_dot(&a) - serial).abs() <= 1e-12 * (1.0 + serial),
                    "n={n} knob={knob}: {} vs serial {serial}",
                    norm2_dot(&a)
                );
            }
            // norm_inf's unrolled combine is bitwise the serial fold (max
            // is associative and commutative on non-NaN input).
            let folded = a.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
            assert_eq!(norm_inf(&a).to_bits(), folded.to_bits(), "n={n}");
        }
        assert_eq!(norm2(&[]), 0.0);
        assert_eq!(norm_inf(&[]), 0.0);
    }

    #[test]
    fn lane_gather_scatter_unrolled_match_reference_loops() {
        // The width-unrolled bodies are pure copies: pin them bitwise
        // against the plain strided loops they replaced, across component
        // counts with and without a 4-tail and ragged lane widths.
        let mut rng = Pcg64::new(92);
        for comps in [1usize, 2, 4, 5, 8, 9, 16] {
            for lanes in [1usize, 2, 3, 5, 8, MAX_LANES] {
                let mut block = vec![0.0; comps * lanes];
                rng.fill_normal(&mut block);
                for lane in 0..lanes {
                    let mut dst = vec![0.0; comps];
                    lane_gather(&block, lane, lanes, &mut dst);
                    for (c, d) in dst.iter().enumerate() {
                        assert_eq!(
                            d.to_bits(),
                            block[c * lanes + lane].to_bits(),
                            "gather comps={comps} lanes={lanes} lane={lane} c={c}"
                        );
                    }
                }
                let mut got = vec![0.0; comps * lanes];
                let mut want = vec![0.0; comps * lanes];
                for lane in 0..lanes {
                    let mut src = vec![0.0; comps];
                    rng.fill_normal(&mut src);
                    lane_scatter(&src, lane, lanes, &mut got);
                    for (c, s) in src.iter().enumerate() {
                        want[c * lanes + lane] = *s;
                    }
                }
                for (u, v) in got.iter().zip(want.iter()) {
                    assert_eq!(u.to_bits(), v.to_bits(), "scatter comps={comps} lanes={lanes}");
                }
            }
        }
    }

    #[test]
    fn simd_dispatch_off_is_bitwise_scalar() {
        // With the knob off, the public kernels must be the scalar
        // reference kernels bit for bit — the "EES_SIMD=0 is untouched"
        // half of the determinism pin (the engine-level half lives in
        // rust/tests/determinism.rs). Without the `simd` feature the
        // toggle is inert and this pins the dispatchers fold to scalar.
        // The guard restores whatever mode the suite was launched with
        // (e.g. the EES_SIMD=1 CI leg) when this test ends.
        let _off = simd_override(false);
        #[cfg(not(feature = "simd"))]
        {
            let _on = simd_override(true); // inert without the feature
            assert!(!simd_enabled());
        }
        #[cfg(feature = "simd")]
        assert!(!simd_enabled());
        let mut rng = Pcg64::new(93);
        for n in [1usize, 4, 7, 16, 33] {
            let mut a = vec![0.0; n * n];
            let mut x = vec![0.0; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut x);
            assert_eq!(
                dot(&a[..n], &x).to_bits(),
                dot_scalar(&a[..n], &x).to_bits(),
                "dot n={n}"
            );
            assert_eq!(
                dot_strided(&a, 0, n, &x).to_bits(),
                dot_strided_scalar(&a, 0, n, &x).to_bits(),
                "dot_strided n={n}"
            );
            let mut y1 = vec![0.0; n];
            let mut y2 = vec![0.0; n];
            matvec(&a, &x, &mut y1, n, n);
            matvec_scalar(&a, &x, &mut y2, n, n);
            matvec_t(&a, &x, &mut y1, n, n);
            matvec_t_scalar(&a, &x, &mut y2, n, n);
            for (u, v) in y1.iter().zip(y2.iter()) {
                assert_eq!(u.to_bits(), v.to_bits(), "matvec_t n={n}");
            }
            let mut c1 = vec![0.0; n * n];
            let mut c2 = vec![0.0; n * n];
            matmul(&a, &a, &mut c1, n, n, n);
            matmul_scalar(&a, &a, &mut c2, n, n, n);
            for (u, v) in c1.iter().zip(c2.iter()) {
                assert_eq!(u.to_bits(), v.to_bits(), "matmul n={n}");
            }
            let lanes = 8;
            let mut xl = vec![0.0; n * lanes];
            rng.fill_normal(&mut xl);
            let mut o1 = vec![0.0; n * lanes];
            let mut o2 = vec![0.0; n * lanes];
            matmul_lanes(&a, &xl, &mut o1, n, n, lanes);
            matmul_lanes_scalar(&a, &xl, &mut o2, n, n, lanes);
            for (u, v) in o1.iter().zip(o2.iter()) {
                assert_eq!(u.to_bits(), v.to_bits(), "matmul_lanes n={n}");
            }
        }
    }
}
