//! Explicit-width SIMD kernels for the lane-major hot path (feature
//! `simd`).
//!
//! Two layers, per the offline dependency policy (no `packed_simd`, no
//! nightly `std::simd`):
//!
//! 1. **Portable lane structs** ([`F64x4`], [`F64x8`]): plain `[f64; W]`
//!    wrappers whose elementwise ops unroll to straight-line code LLVM
//!    reliably lowers to vector instructions. The portable kernels do NOT
//!    reassociate any reduction — [`dot`] packs the scalar kernel's four
//!    independent accumulators into one [`F64x4`] (same products, same
//!    `(s0+s1)+(s2+s3)` combine, same sequential tail), and the lane-major
//!    kernels ([`matmul_lanes`], [`axpy`], [`add_scalar`]) vectorise the
//!    *lane* dimension, whose lanes are independent by construction. The
//!    portable arm is therefore **bitwise-identical** to the scalar
//!    reference kernels — what the explicit structs buy is guaranteed
//!    packing and the removal of per-element bounds checks, not a
//!    different answer.
//! 2. **`std::arch` specialisation** ([`avx2`]): an AVX2+FMA dot kernel
//!    that only compiles when `target_feature = "avx2"` and `"fma"` are
//!    statically enabled (e.g. `RUSTFLAGS="-C target-cpu=native"`). Fused
//!    multiply-add contracts the portable arm's mul-then-add, so this arm
//!    is only *tolerance*-equal to scalar — the reason the public
//!    conformance contract for `EES_SIMD=1` is the ULP bound pinned by the
//!    tests below, not bitwise equality, and the reason the scalar order
//!    stays the default (see `docs/ARCHITECTURE.md` §SIMD kernels & the
//!    determinism contract). No NEON specialisation is shipped: aarch64
//!    enables `neon` by default, which would put intrinsics on the default
//!    build path of every ARM host instead of behind an opt-in.
//!
//! Dispatch happens in the parent module: the public `linalg` kernels
//! check [`super::simd_enabled`] (the `EES_SIMD` / `[exec] simd` knob) and
//! route here, so callers never name these functions directly. All scratch
//! is stack-resident — the SIMD arm inherits the zero-allocation contract
//! (`rust/tests/alloc_regression.rs` pins it with the knob forced on).

use super::MAX_LANES;

/// Four f64 lanes over `[f64; 4]`. Elementwise ops only — no horizontal
/// reassociation except [`Self::hsum`], which hard-codes the scalar `dot`
/// combine `(s0+s1)+(s2+s3)`.
#[derive(Clone, Copy, Debug)]
pub struct F64x4(pub [f64; 4]);

impl F64x4 {
    /// Vector width.
    pub const LANES: usize = 4;

    /// All lanes set to `v`.
    #[inline(always)]
    pub fn splat(v: f64) -> Self {
        Self([v; 4])
    }

    /// Load 4 consecutive values from the front of `s`.
    #[inline(always)]
    pub fn load(s: &[f64]) -> Self {
        Self([s[0], s[1], s[2], s[3]])
    }

    /// Store into the front of `d`.
    #[inline(always)]
    pub fn store(self, d: &mut [f64]) {
        d[..4].copy_from_slice(&self.0);
    }

    /// Elementwise sum.
    #[inline(always)]
    pub fn add(self, o: Self) -> Self {
        let (a, b) = (self.0, o.0);
        Self([a[0] + b[0], a[1] + b[1], a[2] + b[2], a[3] + b[3]])
    }

    /// Elementwise product.
    #[inline(always)]
    pub fn mul(self, o: Self) -> Self {
        let (a, b) = (self.0, o.0);
        Self([a[0] * b[0], a[1] * b[1], a[2] * b[2], a[3] * b[3]])
    }

    /// `self + a·b` elementwise, spelled mul-then-add (never `f64::mul_add`
    /// — a fused contraction would change the float ops vs the scalar
    /// kernels, and lowers to a libm call on targets without hardware FMA).
    #[inline(always)]
    pub fn mul_add_acc(self, a: Self, b: Self) -> Self {
        self.add(a.mul(b))
    }

    /// Horizontal sum in the scalar [`super::dot_scalar`] combine order:
    /// `(s0 + s1) + (s2 + s3)`.
    #[inline(always)]
    pub fn hsum(self) -> f64 {
        (self.0[0] + self.0[1]) + (self.0[2] + self.0[3])
    }
}

/// Eight f64 lanes over `[f64; 8]` — the natural width for the default
/// lane-group size (`EES_LANES=8`) and one AVX-512 register. Elementwise
/// ops only; the lane-major kernels never reduce across these lanes.
#[derive(Clone, Copy, Debug)]
pub struct F64x8(pub [f64; 8]);

impl F64x8 {
    /// Vector width.
    pub const LANES: usize = 8;

    /// All lanes set to `v`.
    #[inline(always)]
    pub fn splat(v: f64) -> Self {
        Self([v; 8])
    }

    /// Load 8 consecutive values from the front of `s`.
    #[inline(always)]
    pub fn load(s: &[f64]) -> Self {
        Self([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]])
    }

    /// Store into the front of `d`.
    #[inline(always)]
    pub fn store(self, d: &mut [f64]) {
        d[..8].copy_from_slice(&self.0);
    }

    /// Elementwise sum.
    #[inline(always)]
    pub fn add(self, o: Self) -> Self {
        let (a, b) = (self.0, o.0);
        let mut r = [0.0f64; 8];
        let mut i = 0;
        while i < 8 {
            r[i] = a[i] + b[i];
            i += 1;
        }
        Self(r)
    }

    /// Elementwise product.
    #[inline(always)]
    pub fn mul(self, o: Self) -> Self {
        let (a, b) = (self.0, o.0);
        let mut r = [0.0f64; 8];
        let mut i = 0;
        while i < 8 {
            r[i] = a[i] * b[i];
            i += 1;
        }
        Self(r)
    }

    /// `self + a·b` elementwise (mul-then-add, see [`F64x4::mul_add_acc`]).
    #[inline(always)]
    pub fn mul_add_acc(self, a: Self, b: Self) -> Self {
        self.add(a.mul(b))
    }
}

/// SIMD dot product. Portable arm: the scalar kernel's four accumulators
/// packed into one [`F64x4`] — bitwise-identical to
/// [`super::dot_scalar`]. On an AVX2+FMA build this dispatches to
/// [`avx2::dot`] instead (tolerance-equal only).
#[cfg(all(target_arch = "x86_64", target_feature = "avx2", target_feature = "fma"))]
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    // SAFETY: this arm only compiles when avx2+fma are statically enabled.
    unsafe { avx2::dot(a, b) }
}

/// SIMD dot product. Portable arm: the scalar kernel's four accumulators
/// packed into one [`F64x4`] — bitwise-identical to
/// [`super::dot_scalar`]. (An AVX2+FMA build replaces this with
/// `avx2::dot`, which is tolerance-equal only.)
#[cfg(not(all(target_arch = "x86_64", target_feature = "avx2", target_feature = "fma")))]
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    dot_portable(a, b)
}

/// The portable vector dot (always available; [`dot`] is this unless the
/// AVX2+FMA specialisation is compiled in).
#[inline]
pub fn dot_portable(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let mut acc = F64x4::splat(0.0);
    for c in 0..chunks {
        let i = 4 * c;
        acc = acc.mul_add_acc(F64x4::load(&a[i..i + 4]), F64x4::load(&b[i..i + 4]));
    }
    let mut s = acc.hsum();
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// SIMD strided dot: gathers each 4-chunk of the strided operand into an
/// [`F64x4`] and reduces in exactly the scalar order — bitwise-identical
/// to [`super::dot_strided_scalar`].
#[inline]
pub fn dot_strided(a: &[f64], offset: usize, stride: usize, x: &[f64]) -> f64 {
    let n = x.len();
    let chunks = n / 4;
    let mut acc = F64x4::splat(0.0);
    for c in 0..chunks {
        let i = 4 * c;
        let g = F64x4([
            a[offset + i * stride],
            a[offset + (i + 1) * stride],
            a[offset + (i + 2) * stride],
            a[offset + (i + 3) * stride],
        ]);
        acc = acc.mul_add_acc(g, F64x4::load(&x[i..i + 4]));
    }
    let mut s = acc.hsum();
    for i in 4 * chunks..n {
        s += a[offset + i * stride] * x[i];
    }
    s
}

/// SIMD y = A·x (row-major m×n): each row reduced with [`dot`].
pub fn matvec(a: &[f64], x: &[f64], y: &mut [f64], m: usize, n: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(y.len(), m);
    for (yi, row) in y.iter_mut().zip(a.chunks_exact(n)).take(m) {
        *yi = dot(row, x);
    }
}

/// SIMD y = Aᵀ·x: each output reduced with [`dot_strided`] (gathered
/// 4-chunks, scalar accumulation order).
pub fn matvec_t(a: &[f64], x: &[f64], y: &mut [f64], m: usize, n: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), m);
    for (j, yj) in y.iter_mut().enumerate().take(n) {
        *yj = dot_strided(a, j, n, x);
    }
}

/// SIMD C = A·B: the scalar kernel's 4-row register blocking with the
/// C-row update vectorised over `j` in [`F64x8`]/[`F64x4`] blocks. Per
/// output element the float ops match [`super::matmul_scalar`] exactly
/// (same `(a0·b0 + a1·b1) + (a2·b2 + a3·b3)` combine, same zero-skip on
/// the k-tail), so the portable arm is bitwise-identical.
pub fn matmul(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        crow.fill(0.0);
        let mut p = 0;
        while p + 4 <= k {
            let (a0, a1, a2, a3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            let b0 = &b[p * n..(p + 1) * n];
            let b1 = &b[(p + 1) * n..(p + 2) * n];
            let b2 = &b[(p + 2) * n..(p + 3) * n];
            let b3 = &b[(p + 3) * n..(p + 4) * n];
            let (va0, va1) = (F64x8::splat(a0), F64x8::splat(a1));
            let (va2, va3) = (F64x8::splat(a2), F64x8::splat(a3));
            let mut j = 0;
            while j + 8 <= n {
                let t01 = va0
                    .mul(F64x8::load(&b0[j..j + 8]))
                    .add(va1.mul(F64x8::load(&b1[j..j + 8])));
                let t23 = va2
                    .mul(F64x8::load(&b2[j..j + 8]))
                    .add(va3.mul(F64x8::load(&b3[j..j + 8])));
                F64x8::load(&crow[j..j + 8])
                    .add(t01.add(t23))
                    .store(&mut crow[j..j + 8]);
                j += 8;
            }
            while j < n {
                crow[j] += (a0 * b0[j] + a1 * b1[j]) + (a2 * b2[j] + a3 * b3[j]);
                j += 1;
            }
            p += 4;
        }
        while p < k {
            let ap = arow[p];
            if ap != 0.0 {
                axpy(crow, ap, &b[p * n..(p + 1) * n]);
            }
            p += 1;
        }
    }
}

/// SIMD lane-blocked GEMM (see [`super::matmul_lanes`] for the layout).
/// The lane dimension is vectorised — lanes are independent, so the
/// per-lane reduction order over `k` is untouched and the result is
/// bitwise-identical to [`super::matmul_lanes_scalar`]. Widths 4/8/16 run
/// fully vectorised; other widths fall back to the scalar kernel (same
/// bits either way).
pub fn matmul_lanes(a: &[f64], x: &[f64], out: &mut [f64], m: usize, k_dim: usize, lanes: usize) {
    assert!(lanes >= 1 && lanes <= MAX_LANES, "lanes {lanes} out of range");
    debug_assert_eq!(a.len(), m * k_dim);
    debug_assert_eq!(x.len(), k_dim * lanes);
    debug_assert_eq!(out.len(), m * lanes);
    match lanes {
        4 => matmul_lanes_blocks::<1>(a, x, out, m, k_dim),
        8 => matmul_lanes_blocks::<2>(a, x, out, m, k_dim),
        16 => matmul_lanes_blocks::<4>(a, x, out, m, k_dim),
        _ => super::matmul_lanes_scalar(a, x, out, m, k_dim, lanes),
    }
}

/// [`matmul_lanes`] body for `lanes = 4·B`: the scalar kernel's four
/// k-accumulators, each held as `B` [`F64x4`] registers over the lane
/// dimension.
fn matmul_lanes_blocks<const B: usize>(
    a: &[f64],
    x: &[f64],
    out: &mut [f64],
    m: usize,
    k_dim: usize,
) {
    let lanes = 4 * B;
    let chunks = k_dim / 4;
    for i in 0..m {
        let row = &a[i * k_dim..(i + 1) * k_dim];
        let mut s0 = [F64x4::splat(0.0); B];
        let mut s1 = [F64x4::splat(0.0); B];
        let mut s2 = [F64x4::splat(0.0); B];
        let mut s3 = [F64x4::splat(0.0); B];
        for c in 0..chunks {
            let k = 4 * c;
            let a0 = F64x4::splat(row[k]);
            let a1 = F64x4::splat(row[k + 1]);
            let a2 = F64x4::splat(row[k + 2]);
            let a3 = F64x4::splat(row[k + 3]);
            let x0 = &x[k * lanes..(k + 1) * lanes];
            let x1 = &x[(k + 1) * lanes..(k + 2) * lanes];
            let x2 = &x[(k + 2) * lanes..(k + 3) * lanes];
            let x3 = &x[(k + 3) * lanes..(k + 4) * lanes];
            for blk in 0..B {
                let o = 4 * blk;
                s0[blk] = s0[blk].mul_add_acc(a0, F64x4::load(&x0[o..o + 4]));
                s1[blk] = s1[blk].mul_add_acc(a1, F64x4::load(&x1[o..o + 4]));
                s2[blk] = s2[blk].mul_add_acc(a2, F64x4::load(&x2[o..o + 4]));
                s3[blk] = s3[blk].mul_add_acc(a3, F64x4::load(&x3[o..o + 4]));
            }
        }
        let orow = &mut out[i * lanes..(i + 1) * lanes];
        for blk in 0..B {
            let o = 4 * blk;
            s0[blk]
                .add(s1[blk])
                .add(s2[blk].add(s3[blk]))
                .store(&mut orow[o..o + 4]);
        }
        for k in 4 * chunks..k_dim {
            let ak = F64x4::splat(row[k]);
            let xk = &x[k * lanes..(k + 1) * lanes];
            for blk in 0..B {
                let o = 4 * blk;
                F64x4::load(&orow[o..o + 4])
                    .mul_add_acc(ak, F64x4::load(&xk[o..o + 4]))
                    .store(&mut orow[o..o + 4]);
            }
        }
    }
}

/// y[i] += v, vectorised — the lane-major bias-add of the MLP forward
/// epilogue ([`crate::nn::Mlp::forward_lanes`]). Elementwise, so bitwise
/// equal to the scalar loop.
#[inline]
pub fn add_scalar(y: &mut [f64], v: f64) {
    let n = y.len();
    let vv = F64x4::splat(v);
    let chunks = n / 4;
    for c in 0..chunks {
        let i = 4 * c;
        F64x4::load(&y[i..i + 4]).add(vv).store(&mut y[i..i + 4]);
    }
    for yi in y[4 * chunks..].iter_mut() {
        *yi += v;
    }
}

/// y += a·x elementwise, vectorised — the lane-major Wᵀδ accumulation of
/// the MLP backward epilogue ([`crate::nn::Mlp::vjp_lanes`]) and the
/// k-tail of [`matmul`]. Elementwise (no reduction), so bitwise equal to
/// the scalar loop.
#[inline]
pub fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    let n = y.len().min(x.len());
    let va = F64x4::splat(a);
    let chunks = n / 4;
    for c in 0..chunks {
        let i = 4 * c;
        F64x4::load(&y[i..i + 4])
            .mul_add_acc(va, F64x4::load(&x[i..i + 4]))
            .store(&mut y[i..i + 4]);
    }
    for i in 4 * chunks..n {
        y[i] += a * x[i];
    }
}

/// AVX2+FMA specialisation — only compiled when both target features are
/// statically enabled (`RUSTFLAGS="-C target-cpu=native"` or
/// `-C target-feature=+avx2,+fma`), so a default build carries no
/// `std::arch` code at all. `_mm256_fmadd_pd` contracts the portable
/// arm's mul-then-add into a fused op: faster and *more* accurate per
/// element, but no longer bitwise-equal to the scalar kernels — with this
/// arm active, `EES_SIMD=1` only promises the ULP conformance bound.
#[cfg(all(target_arch = "x86_64", target_feature = "avx2", target_feature = "fma"))]
pub mod avx2 {
    #[cfg(target_arch = "x86_64")]
    use core::arch::x86_64::*;

    /// Fused-multiply-add dot over 256-bit lanes; horizontal combine in
    /// the scalar `(s0+s1)+(s2+s3)` order, sequential tail.
    ///
    /// # Safety
    /// Only compiled when `avx2`/`fma` are statically enabled, so the
    /// intrinsics are always supported at runtime.
    #[inline]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len().min(b.len());
        let chunks = n / 4;
        let mut acc = _mm256_setzero_pd();
        for c in 0..chunks {
            let i = 4 * c;
            let va = _mm256_loadu_pd(a.as_ptr().add(i));
            let vb = _mm256_loadu_pd(b.as_ptr().add(i));
            acc = _mm256_fmadd_pd(va, vb, acc);
        }
        let mut buf = [0.0f64; 4];
        _mm256_storeu_pd(buf.as_mut_ptr(), acc);
        let mut s = (buf[0] + buf[1]) + (buf[2] + buf[3]);
        for i in 4 * chunks..n {
            s += a[i] * b[i];
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// |got − want| within `ulps` units of a conservative error scale for
    /// an n-term reduction: Σ|terms| · n · ε. The bound holds for every
    /// compiled specialisation (portable is exact; FMA contraction shifts
    /// each partial by ≤ ½ulp).
    fn assert_reduction_close(got: f64, want: f64, abs_terms: f64, n: usize, what: &str) {
        let scale = abs_terms.max(1e-300) * (n.max(2) as f64);
        let tol = 4.0 * f64::EPSILON * scale;
        assert!(
            (got - want).abs() <= tol,
            "{what}: got {got}, want {want}, tol {tol}"
        );
    }

    #[test]
    fn dot_conformance_dims_1_to_64() {
        let mut rng = Pcg64::new(1001);
        for n in 1usize..=64 {
            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            let want = super::super::dot_scalar(&a, &b);
            let abs: f64 = a.iter().zip(b.iter()).map(|(x, y)| (x * y).abs()).sum();
            // ULP-tolerance contract: holds for every specialisation.
            assert_reduction_close(dot(&a, &b), want, abs, n, &format!("dot n={n}"));
            // The portable arm is exactly the scalar kernel, bit for bit.
            assert_eq!(dot_portable(&a, &b).to_bits(), want.to_bits(), "n={n}");
            // Strided variant, contiguous embedding.
            assert_eq!(
                dot_strided(&a, 0, 1, &b).to_bits(),
                super::super::dot_strided_scalar(&a, 0, 1, &b).to_bits(),
                "strided n={n}"
            );
        }
    }

    #[test]
    fn dot_strided_gather_matches_scalar_bitwise() {
        let mut rng = Pcg64::new(1002);
        for n in [1usize, 3, 4, 7, 8, 13, 32, 64] {
            let stride = 5;
            let mut wide = vec![0.0; n * stride + 2];
            let mut x = vec![0.0; n];
            rng.fill_normal(&mut wide);
            rng.fill_normal(&mut x);
            assert_eq!(
                dot_strided(&wide, 2, stride, &x).to_bits(),
                super::super::dot_strided_scalar(&wide, 2, stride, &x).to_bits(),
                "n={n}"
            );
        }
    }

    #[test]
    fn matvec_and_transpose_match_scalar() {
        let mut rng = Pcg64::new(1003);
        for (m, n) in [(1usize, 1usize), (4, 4), (7, 3), (16, 16), (5, 64)] {
            let mut a = vec![0.0; m * n];
            rng.fill_normal(&mut a);
            let mut x = vec![0.0; n];
            rng.fill_normal(&mut x);
            let mut y_simd = vec![0.0; m];
            let mut y_ref = vec![0.0; m];
            matvec(&a, &x, &mut y_simd, m, n);
            super::super::matvec_scalar(&a, &x, &mut y_ref, m, n);
            for (i, (u, v)) in y_simd.iter().zip(y_ref.iter()).enumerate() {
                let abs: f64 = (0..n).map(|j| (a[i * n + j] * x[j]).abs()).sum();
                assert_reduction_close(*u, *v, abs, n, &format!("matvec ({m},{n})[{i}]"));
            }
            let mut xt = vec![0.0; m];
            rng.fill_normal(&mut xt);
            let mut yt_simd = vec![0.0; n];
            let mut yt_ref = vec![0.0; n];
            matvec_t(&a, &xt, &mut yt_simd, m, n);
            super::super::matvec_t_scalar(&a, &xt, &mut yt_ref, m, n);
            for (u, v) in yt_simd.iter().zip(yt_ref.iter()) {
                assert_eq!(u.to_bits(), v.to_bits(), "matvec_t ({m},{n})");
            }
        }
    }

    #[test]
    fn matmul_matches_scalar_bitwise() {
        // The portable matmul keeps the scalar float ops exactly — j is
        // vectorised, the k-order is untouched. Shapes cover the 8-wide j
        // body, the j tail, the 4-blocked k body and the zero-skipping k
        // tail.
        let mut rng = Pcg64::new(1004);
        for (m, k, n) in [
            (1usize, 1usize, 1usize),
            (2, 4, 8),
            (3, 5, 7),
            (4, 11, 16),
            (5, 8, 9),
            (7, 6, 3),
        ] {
            let mut a = vec![0.0; m * k];
            let mut b = vec![0.0; k * n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            // Sprinkle exact zeros so the k-tail skip path is exercised.
            if k % 4 != 0 {
                a[(m - 1) * k + (k - 1)] = 0.0;
            }
            let mut c_simd = vec![0.0; m * n];
            let mut c_ref = vec![0.0; m * n];
            matmul(&a, &b, &mut c_simd, m, k, n);
            super::super::matmul_scalar(&a, &b, &mut c_ref, m, k, n);
            for (u, v) in c_simd.iter().zip(c_ref.iter()) {
                assert_eq!(u.to_bits(), v.to_bits(), "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn matmul_lanes_matches_scalar_bitwise_all_widths() {
        // Every lane width 1–16, k with and without a tail: the vectorised
        // widths (4/8/16) and the scalar fallback must both be bitwise the
        // scalar kernel.
        let mut rng = Pcg64::new(1005);
        for lanes in 1usize..=MAX_LANES {
            for (m, k) in [(3usize, 8usize), (5, 11), (2, 1), (4, 4)] {
                let mut a = vec![0.0; m * k];
                let mut x = vec![0.0; k * lanes];
                rng.fill_normal(&mut a);
                rng.fill_normal(&mut x);
                let mut out_simd = vec![0.0; m * lanes];
                let mut out_ref = vec![0.0; m * lanes];
                matmul_lanes(&a, &x, &mut out_simd, m, k, lanes);
                super::super::matmul_lanes_scalar(&a, &x, &mut out_ref, m, k, lanes);
                for (u, v) in out_simd.iter().zip(out_ref.iter()) {
                    assert_eq!(u.to_bits(), v.to_bits(), "lanes={lanes} m={m} k={k}");
                }
            }
        }
    }

    #[test]
    fn epilogue_helpers_match_scalar_loops_bitwise() {
        let mut rng = Pcg64::new(1006);
        for n in [1usize, 3, 4, 5, 8, 13, 16] {
            let mut y = vec![0.0; n];
            rng.fill_normal(&mut y);
            let mut y_ref = y.clone();
            add_scalar(&mut y, 0.37);
            for v in y_ref.iter_mut() {
                *v += 0.37;
            }
            assert_eq!(y, y_ref, "add_scalar n={n}");

            let mut x = vec![0.0; n];
            rng.fill_normal(&mut x);
            let mut y2_ref = y.clone();
            axpy(&mut y, -1.25, &x);
            for (v, xi) in y2_ref.iter_mut().zip(x.iter()) {
                *v += -1.25 * xi;
            }
            for (u, v) in y.iter().zip(y2_ref.iter()) {
                assert_eq!(u.to_bits(), v.to_bits(), "axpy n={n}");
            }
        }
    }

    #[test]
    fn simd_arm_is_run_to_run_deterministic() {
        // At a fixed width the SIMD kernels are pure functions of their
        // inputs — repeated calls must agree bit for bit (this also holds
        // for the FMA specialisation when compiled in).
        let mut rng = Pcg64::new(1007);
        let (m, k, lanes) = (6usize, 16usize, 8usize);
        let mut a = vec![0.0; m * k];
        let mut x = vec![0.0; k * lanes];
        rng.fill_normal(&mut a);
        rng.fill_normal(&mut x);
        let d1 = dot(&a[..k], &a[k..2 * k]);
        let d2 = dot(&a[..k], &a[k..2 * k]);
        assert_eq!(d1.to_bits(), d2.to_bits());
        let mut o1 = vec![0.0; m * lanes];
        let mut o2 = vec![0.0; m * lanes];
        matmul_lanes(&a, &x, &mut o1, m, k, lanes);
        matmul_lanes(&a, &x, &mut o2, m, k, lanes);
        for (u, v) in o1.iter().zip(o2.iter()) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }
}
