//! Deterministic parallel map over batch samples — the engine under the
//! coordinator's batch forward/backward sweeps.
//!
//! Design constraints (in priority order):
//!
//! 1. **Bitwise determinism**: the output of any computation built on
//!    [`parallel_map`] must be identical for every worker count, including 1.
//!    This is achieved by keying every result to its sample index and doing
//!    all floating-point *reductions* in fixed index order at the call site —
//!    the map itself never combines two samples' numbers.
//! 2. **Zero dependencies**: the offline build has no rayon, so the engine
//!    is built on `std::thread::scope` (see the dependency policy in
//!    `Cargo.toml`). The API is shaped so a rayon backend can be swapped in
//!    behind the same function without touching call sites.
//! 3. **Load balance**: samples are handed out through a shared atomic
//!    counter (work stealing), so a slow sample does not idle the other
//!    workers the way static chunking would.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `0..n` with up to `parallelism` worker threads, returning
/// the results in index order.
///
/// The result is **independent of the worker count**: each index's value is
/// computed by exactly one worker and placed back by index. `parallelism`
/// values of 0 or 1 (or `n <= 1`) run inline on the calling thread with no
/// spawn overhead.
///
/// Worker panics are propagated to the caller.
pub fn parallel_map<T, F>(parallelism: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = parallelism.max(1).min(n);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let per_worker: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut acc = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        acc.push((i, f(i)));
                    }
                    acc
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                // Re-raise the original payload so a worker's panic message
                // and location survive to the caller's backtrace.
                h.join()
                    .unwrap_or_else(|e| std::panic::resume_unwind(e))
            })
            .collect()
    });
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for chunk in per_worker {
        for (i, v) in chunk {
            debug_assert!(slots[i].is_none(), "index {i} produced twice");
            slots[i] = Some(v);
        }
    }
    slots
        .into_iter()
        .map(|s| s.expect("parallel_map slot unfilled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        for p in [0, 1, 2, 4, 16] {
            let out = parallel_map(p, 100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>(), "p={p}");
        }
    }

    #[test]
    fn handles_n_smaller_than_workers() {
        assert_eq!(parallel_map(8, 2, |i| i + 1), vec![1, 2]);
        assert_eq!(parallel_map(8, 0, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn results_identical_across_worker_counts() {
        // A numeric workload whose per-index result must not depend on
        // scheduling: each index runs its own deterministic RNG stream.
        let run = |p: usize| -> Vec<u64> {
            parallel_map(p, 32, |i| {
                let mut rng = crate::rng::Pcg64::new(1000 + i as u64);
                (0..50).map(|_| rng.next_u64()).fold(0u64, u64::wrapping_add)
            })
        };
        let seq = run(1);
        for p in [2, 3, 8] {
            assert_eq!(run(p), seq, "p={p}");
        }
    }

    #[test]
    fn actually_uses_multiple_threads() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let ids: Mutex<HashSet<std::thread::ThreadId>> = Mutex::new(HashSet::new());
        parallel_map(4, 64, |_| {
            ids.lock().unwrap().insert(std::thread::current().id());
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        assert!(ids.lock().unwrap().len() > 1, "expected >1 worker thread");
    }
}
