//! Training coordinator: the L3 orchestration layer.
//!
//! Owns the training loop of every experiment — batch trajectory generation
//! over per-sample Brownian drivers, batch-loss evaluation, per-sample
//! backward sweeps through the chosen adjoint, gradient aggregation/clipping
//! and optimiser steps — plus runtime/eval/memory metric logging. Python is
//! never on this path; the compiled-artifact mode executes the AOT JAX/
//! Pallas step function through [`crate::runtime`] instead of the native
//! field.

use crate::adjoint::AdjointMethod;
use crate::lie::HomogeneousSpace;
use crate::losses::BatchLoss;
use crate::memory::{MemMeter, MeteredTape};
use crate::nn::optim::{clip_global_norm, Optimizer};
use crate::rng::{BrownianPath, Pcg64};
use crate::solvers::{ManifoldStepper, Stepper};
use crate::vf::{DiffManifoldVectorField, DiffVectorField};
use std::time::Instant;

/// One epoch's metrics.
#[derive(Clone, Debug)]
pub struct EpochMetrics {
    pub epoch: usize,
    pub loss: f64,
    pub grad_norm: f64,
    pub peak_mem_f64s: usize,
    pub wall_secs: f64,
}

/// Result of a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub history: Vec<EpochMetrics>,
    pub total_secs: f64,
}

impl TrainLog {
    pub fn terminal_loss(&self) -> f64 {
        self.history.last().map(|m| m.loss).unwrap_or(f64::NAN)
    }
    pub fn peak_mem(&self) -> usize {
        self.history
            .iter()
            .map(|m| m.peak_mem_f64s)
            .max()
            .unwrap_or(0)
    }
}

/// Batch forward+backward for a Euclidean neural SDE under a batch loss.
/// Returns (loss, d_theta, peak adjoint memory).
#[allow(clippy::too_many_arguments)]
pub fn batch_grad_euclidean(
    stepper: &dyn Stepper,
    method: AdjointMethod,
    vf: &dyn DiffVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
) -> (f64, Vec<f64>, usize) {
    let batch = y0s.len();
    let dim = vf.dim();
    let n_obs = obs.len();
    let steps = paths[0].steps();
    let h = paths[0].h;
    let state_size = stepper.state_size(dim);
    let mut meter = MemMeter::new();
    meter.alloc(2 * state_size + batch * n_obs * dim);

    let seg = (steps as f64).sqrt().ceil() as usize;
    // Forward all samples, keeping per-sample terminal state (Reversible),
    // checkpoints (Recursive) or full tapes (Full).
    let mut finals: Vec<Vec<f64>> = Vec::with_capacity(batch);
    let mut tapes: Vec<MeteredTape> = (0..batch).map(|_| MeteredTape::new()).collect();
    let mut obs_states = vec![0.0; batch * n_obs * dim];
    for b in 0..batch {
        let mut state = stepper.init_state(vf, 0.0, &y0s[b]);
        if method != AdjointMethod::Reversible {
            tapes[b].push(&state, &mut meter);
        }
        let mut oi = 0;
        for n in 0..steps {
            let t = n as f64 * h;
            stepper.step(vf, t, h, paths[b].increment(n), &mut state);
            match method {
                AdjointMethod::Full => tapes[b].push(&state, &mut meter),
                AdjointMethod::Recursive => {
                    if (n + 1) % seg == 0 {
                        tapes[b].push(&state, &mut meter);
                    }
                }
                AdjointMethod::Reversible => {}
            }
            while oi < n_obs && obs[oi] == n + 1 {
                obs_states[(b * n_obs + oi) * dim..(b * n_obs + oi + 1) * dim]
                    .copy_from_slice(&state[..dim]);
                oi += 1;
            }
        }
        finals.push(state);
    }
    let (loss_val, cots) = loss.eval_grad(&obs_states, batch, n_obs, dim);

    let mut d_theta = vec![0.0; vf.num_params()];
    meter.alloc(d_theta.len());
    for b in 0..batch {
        let mut lambda = vec![0.0; state_size];
        let mut state = finals[b].clone();
        let mut oi = n_obs;
        let mut seg_buf = MeteredTape::new();
        for n in (0..steps).rev() {
            while oi > 0 && obs[oi - 1] == n + 1 {
                oi -= 1;
                for d in 0..dim {
                    lambda[d] += cots[(b * n_obs + oi) * dim + d];
                }
            }
            let t = n as f64 * h;
            let dw = paths[b].increment(n);
            match method {
                AdjointMethod::Full => {
                    stepper.backprop_step(vf, t, h, dw, tapes[b].get(n), &mut lambda, &mut d_theta);
                }
                AdjointMethod::Reversible => {
                    stepper.step_back(vf, t, h, dw, &mut state);
                    stepper.backprop_step(vf, t, h, dw, &state, &mut lambda, &mut d_theta);
                }
                AdjointMethod::Recursive => {
                    if seg_buf.is_empty() {
                        let seg_start = (n / seg) * seg;
                        let ckpt_idx = n / seg;
                        let mut s = tapes[b].get(ckpt_idx).to_vec();
                        seg_buf.push(&s, &mut meter);
                        for m in seg_start..n {
                            stepper.step(vf, m as f64 * h, h, paths[b].increment(m), &mut s);
                            seg_buf.push(&s, &mut meter);
                        }
                    }
                    let prev = seg_buf.pop(&mut meter).expect("segment buffer underflow");
                    stepper.backprop_step(vf, t, h, dw, &prev, &mut lambda, &mut d_theta);
                }
            }
        }
        tapes[b].clear(&mut meter);
    }
    (loss_val, d_theta, meter.peak_f64s())
}

/// Batch forward+backward on a homogeneous space (Algorithm 2 per sample).
#[allow(clippy::too_many_arguments)]
pub fn batch_grad_manifold(
    stepper: &dyn ManifoldStepper,
    method: AdjointMethod,
    sp: &dyn HomogeneousSpace,
    vf: &dyn DiffManifoldVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
) -> (f64, Vec<f64>, usize) {
    let batch = y0s.len();
    let dim = sp.point_dim();
    let n_obs = obs.len();
    let steps = paths[0].steps();
    let h = paths[0].h;
    let mut meter = MemMeter::new();
    meter.alloc(2 * dim + 2 * sp.algebra_dim() + batch * n_obs * dim);
    let seg = (steps as f64).sqrt().ceil() as usize;

    let mut finals: Vec<Vec<f64>> = Vec::with_capacity(batch);
    let mut tapes: Vec<MeteredTape> = (0..batch).map(|_| MeteredTape::new()).collect();
    let mut obs_states = vec![0.0; batch * n_obs * dim];
    for b in 0..batch {
        let mut y = y0s[b].clone();
        if method != AdjointMethod::Reversible {
            tapes[b].push(&y, &mut meter);
        }
        let mut oi = 0;
        for n in 0..steps {
            stepper.step(sp, vf, n as f64 * h, h, paths[b].increment(n), &mut y);
            match method {
                AdjointMethod::Full => tapes[b].push(&y, &mut meter),
                AdjointMethod::Recursive => {
                    if (n + 1) % seg == 0 {
                        tapes[b].push(&y, &mut meter);
                    }
                }
                AdjointMethod::Reversible => {}
            }
            while oi < n_obs && obs[oi] == n + 1 {
                obs_states[(b * n_obs + oi) * dim..(b * n_obs + oi + 1) * dim]
                    .copy_from_slice(&y);
                oi += 1;
            }
        }
        finals.push(y);
    }
    let (loss_val, cots) = loss.eval_grad(&obs_states, batch, n_obs, dim);

    let mut d_theta = vec![0.0; vf.num_params()];
    meter.alloc(d_theta.len());
    for b in 0..batch {
        let mut lambda = vec![0.0; dim];
        let mut y = finals[b].clone();
        let mut oi = n_obs;
        let mut seg_buf = MeteredTape::new();
        for n in (0..steps).rev() {
            while oi > 0 && obs[oi - 1] == n + 1 {
                oi -= 1;
                for d in 0..dim {
                    lambda[d] += cots[(b * n_obs + oi) * dim + d];
                }
            }
            let t = n as f64 * h;
            let dw = paths[b].increment(n);
            match method {
                AdjointMethod::Full => {
                    stepper.backprop_step(sp, vf, t, h, dw, tapes[b].get(n), &mut lambda, &mut d_theta);
                }
                AdjointMethod::Reversible => {
                    stepper.step_back(sp, vf, t, h, dw, &mut y);
                    stepper.backprop_step(sp, vf, t, h, dw, &y, &mut lambda, &mut d_theta);
                }
                AdjointMethod::Recursive => {
                    if seg_buf.is_empty() {
                        let seg_start = (n / seg) * seg;
                        let ckpt_idx = n / seg;
                        let mut s = tapes[b].get(ckpt_idx).to_vec();
                        seg_buf.push(&s, &mut meter);
                        for m in seg_start..n {
                            stepper.step(sp, vf, m as f64 * h, h, paths[b].increment(m), &mut s);
                            seg_buf.push(&s, &mut meter);
                        }
                    }
                    let prev = seg_buf.pop(&mut meter).expect("segment buffer underflow");
                    stepper.backprop_step(sp, vf, t, h, dw, &prev, &mut lambda, &mut d_theta);
                }
            }
        }
        tapes[b].clear(&mut meter);
    }
    (loss_val, d_theta, meter.peak_f64s())
}

/// Generic Euclidean training loop: params live in `get/set` closures so the
/// coordinator stays model-agnostic.
#[allow(clippy::too_many_arguments)]
pub fn train_euclidean<M, FGet, FSet>(
    model: &mut M,
    get_params: FGet,
    set_params: FSet,
    stepper: &dyn Stepper,
    method: AdjointMethod,
    sample_batch: &mut dyn FnMut(&mut Pcg64) -> (Vec<Vec<f64>>, Vec<BrownianPath>),
    obs: &[usize],
    loss: &dyn BatchLoss,
    opt: &mut Optimizer,
    epochs: usize,
    clip: Option<f64>,
    rng: &mut Pcg64,
) -> TrainLog
where
    M: DiffVectorField,
    FGet: Fn(&M) -> Vec<f64>,
    FSet: Fn(&mut M, &[f64]),
{
    let start = Instant::now();
    let mut log = TrainLog::default();
    for epoch in 0..epochs {
        let e0 = Instant::now();
        let (y0s, paths) = sample_batch(rng);
        let (l, mut grad, peak) =
            batch_grad_euclidean(stepper, method, model, &y0s, &paths, obs, loss);
        let gn = if let Some(c) = clip {
            clip_global_norm(&mut grad, c)
        } else {
            grad.iter().map(|g| g * g).sum::<f64>().sqrt()
        };
        let mut params = get_params(model);
        opt.step(&mut params, &grad);
        set_params(model, &params);
        log.history.push(EpochMetrics {
            epoch,
            loss: l,
            grad_norm: gn,
            peak_mem_f64s: peak,
            wall_secs: e0.elapsed().as_secs_f64(),
        });
    }
    log.total_secs = start.elapsed().as_secs_f64();
    log
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::MomentMatch;
    use crate::models::ou::OuParams;
    use crate::nn::neural_sde::NeuralSde;
    use crate::solvers::LowStorageStepper;

    /// End-to-end smoke: a tiny neural SDE trained on OU moments with the
    /// reversible adjoint reduces the loss.
    #[test]
    fn training_reduces_loss_on_ou() {
        let mut rng = Pcg64::new(20);
        let ou = OuParams::default();
        let steps = 16;
        let h = 2.0 / steps as f64;
        let obs: Vec<usize> = (4..=steps).step_by(4).collect();
        // Exact-moment targets at the observation times.
        let (mean_all, m2_all) = ou.moment_targets(0.0, steps, h, 4000, &mut rng);
        let target_mean: Vec<f64> = obs.iter().map(|&i| mean_all[i]).collect();
        let target_m2: Vec<f64> = obs.iter().map(|&i| m2_all[i]).collect();
        let loss = MomentMatch {
            target_mean,
            target_m2,
        };
        let mut model = NeuralSde::lsde(1, 8, 1, true, &mut rng);
        let st = LowStorageStepper::ees25();
        let mut opt = Optimizer::adam(0.02, model.num_params());
        let batch = 64;
        let mut sampler = move |rng: &mut Pcg64| {
            let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.0]).collect();
            let paths: Vec<BrownianPath> = (0..batch)
                .map(|_| BrownianPath::sample(rng, 1, steps, h))
                .collect();
            (y0s, paths)
        };
        let log = train_euclidean(
            &mut model,
            |m: &NeuralSde| m.params(),
            |m: &mut NeuralSde, p: &[f64]| m.set_params(p),
            &st,
            AdjointMethod::Reversible,
            &mut sampler,
            &obs,
            &loss,
            &mut opt,
            40,
            Some(1.0),
            &mut rng,
        );
        let first: f64 = log.history[..5].iter().map(|m| m.loss).sum::<f64>() / 5.0;
        let last: f64 = log.history[35..].iter().map(|m| m.loss).sum::<f64>() / 5.0;
        assert!(
            last < 0.7 * first,
            "loss must decrease: {first} -> {last}"
        );
    }

    /// Batch gradients agree across adjoints (Table-12 property at batch level).
    #[test]
    fn batch_adjoints_agree() {
        let mut rng = Pcg64::new(21);
        let model = NeuralSde::lsde(2, 6, 1, false, &mut rng);
        let st = LowStorageStepper::ees25();
        let steps = 20;
        let h = 0.05;
        let batch = 4;
        let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.1, -0.1]).collect();
        let paths: Vec<BrownianPath> = (0..batch)
            .map(|_| BrownianPath::sample(&mut rng, 2, steps, h))
            .collect();
        let obs = vec![10, 20];
        let mut data = vec![0.0; batch * 2 * 2];
        rng.fill_normal(&mut data);
        let loss = MomentMatch::from_data(&data, batch, 2, 2);
        let (l0, g0, m_full) = batch_grad_euclidean(
            &st,
            AdjointMethod::Full,
            &model,
            &y0s,
            &paths,
            &obs,
            &loss,
        );
        for method in [AdjointMethod::Recursive, AdjointMethod::Reversible] {
            let (l, g, m) =
                batch_grad_euclidean(&st, method, &model, &y0s, &paths, &obs, &loss);
            assert!((l - l0).abs() < 1e-10);
            for (a, b) in g.iter().zip(g0.iter()) {
                assert!((a - b).abs() < 1e-7 * (1.0 + b.abs()), "{a} vs {b}");
            }
            assert!(m < m_full, "{} must use less memory", method.name());
        }
    }
}
