//! Batch-solve coordinator: the L3 orchestration layer under the training
//! engine.
//!
//! Owns batch trajectory generation over per-sample Brownian drivers,
//! batch-loss evaluation, per-sample backward sweeps through the chosen
//! adjoint, and the deterministic gradient reduction — the primitives that
//! [`crate::train::Trainer`] (the training engine that owns every
//! experiment's epoch loop, optimisers, schedules and callbacks) drives
//! once per epoch. Python is never on this path; the compiled-artifact mode
//! executes the AOT JAX/Pallas step function through [`crate::runtime`]
//! instead of the native field.
//!
//! # Parallel batch engine
//!
//! Batch samples are embarrassingly parallel: each trajectory owns its
//! driver, tape and cotangent. The forward sweep and the backward sweep each
//! fan out over samples through [`parallel::parallel_map`]; the batch loss
//! (which genuinely couples samples) is the only sequential barrier between
//! them. Results are **bitwise-deterministic in the worker count**:
//!
//! - per-sample state never crosses threads mid-computation;
//! - the parameter gradient is reduced per sample first, then summed in
//!   fixed batch order;
//! - per-sample noise comes from independent [`Pcg64::split`] streams (see
//!   [`sample_paths_par`]), not from interleaved draws on a shared stream.
//!
//! The worker count comes from the call site (`*_par` variants) or, for the
//! plain-named wrappers, from [`crate::config::default_parallelism`] (the
//! `EES_PARALLELISM` env var, else all available cores). A config-driven
//! harness that parses an `[exec] parallelism` key
//! ([`crate::config::Config::parallelism`]) must hand the value to a
//! `*_par` entry point explicitly.
//!
//! # Memory accounting
//!
//! The adjoint-memory model meters the same quantities as a sequential
//! sweep would: `peak = shared registers + Σ_b retained tape + max_b
//! backward transient` — all tapes coexist after the forward pass, while
//! backward segment buffers are transient per sample. The formula is
//! deterministic in the worker count; per-sample gradient scratch (an
//! artifact of the parallel reduction, `min(workers, batch) · |θ|`) is
//! deliberately excluded, exactly as the sequential meter excluded its
//! single shared accumulator's duplicates.

pub mod parallel;

pub use parallel::parallel_map;

// The per-epoch metric types moved into the training engine when the epoch
// loop did (`crate::train`); these re-exports keep pre-move paths working.
pub use crate::train::{EpochMetrics, TrainLog};

use crate::adjoint::AdjointMethod;
use crate::lie::HomogeneousSpace;
use crate::losses::BatchLoss;
use crate::memory::{MemMeter, MeteredTape, WorkspacePool};
use crate::nn::optim::Optimizer;
use crate::rng::{BrownianPath, BrownianSource, Pcg64, VirtualBrownianTree};
use crate::solvers::{AdaptiveController, AdaptiveResult, ManifoldStepper, Stepper};
use crate::train::{OptimSpec, TrainConfig, TrainProblem, Trainer};
use crate::vf::{DiffManifoldVectorField, DiffVectorField, VectorField};

/// Per-sample output of the forward sweep (tape + observations + terminal
/// solver state), kept alive until the sample's backward sweep consumes it.
struct ForwardOut {
    final_state: Vec<f64>,
    tape: MeteredTape,
    obs_states: Vec<f64>,
    /// f64 slots retained by the tape after the forward pass.
    retained: usize,
}

/// Assemble the batch observation matrix from per-sample forward outputs,
/// in fixed batch order (part of the determinism contract).
fn gather_obs(fwd: &[ForwardOut], n_obs: usize, dim: usize) -> Vec<f64> {
    let mut obs_all = vec![0.0; fwd.len() * n_obs * dim];
    for (b, f) in fwd.iter().enumerate() {
        obs_all[b * n_obs * dim..(b + 1) * n_obs * dim].copy_from_slice(&f.obs_states);
    }
    obs_all
}

/// Reduce per-sample (gradient, backward transient peak) pairs in fixed
/// batch order and apply the shared memory model
/// `base + Σ retained + max transient` — the single source of truth for
/// both the Euclidean and manifold engines (see the module docs).
fn reduce_per_sample(
    per_sample: &[(Vec<f64>, usize)],
    num_params: usize,
    base_mem: usize,
    tape_retained: usize,
) -> (Vec<f64>, usize) {
    let mut d_theta = vec![0.0; num_params];
    let mut backward_peak = 0usize;
    for (g, peak) in per_sample {
        for (acc, v) in d_theta.iter_mut().zip(g.iter()) {
            *acc += v;
        }
        backward_peak = backward_peak.max(*peak);
    }
    (d_theta, base_mem + tape_retained + backward_peak)
}

/// Sample `batch` independent Brownian drivers from per-sample
/// [`Pcg64::split`] streams, generating paths in parallel.
///
/// The per-sample streams are derived **sequentially, in index order, on
/// the calling thread** before any parallel work starts (`split` advances
/// the parent generator, so split order matters — a stream is a function of
/// the parent state *at the time of the split*, not of the index alone).
/// Only the path generation from the already-derived streams fans out,
/// which is why the batch is identical for every `parallelism`.
pub fn sample_paths_par(
    rng: &mut Pcg64,
    batch: usize,
    dim: usize,
    steps: usize,
    h: f64,
    parallelism: usize,
) -> Vec<BrownianPath> {
    let streams: Vec<Pcg64> = (0..batch).map(|b| rng.split(b as u64)).collect();
    parallel_map(parallelism, batch, |b| {
        let mut s = streams[b].clone();
        BrownianPath::sample(&mut s, dim, steps, h)
    })
}

/// Derive `batch` independent [`VirtualBrownianTree`]s over [t0, t1] from
/// per-sample [`Pcg64::split`] streams — the tree analogue of
/// [`sample_paths_par`].
///
/// Seeds are derived **sequentially, in index order, on the calling
/// thread** (the same contract as path sampling: `split` advances the
/// parent generator, so split order is part of the determinism story). The
/// trees themselves are stateless, so no parallel phase is needed at all:
/// handing tree `b` to any worker yields bitwise-identical queries at any
/// worker count.
pub fn sample_trees(
    rng: &mut Pcg64,
    batch: usize,
    dim: usize,
    t0: f64,
    t1: f64,
    depth: u32,
) -> Vec<VirtualBrownianTree> {
    (0..batch)
        .map(|b| {
            let seed = rng.split(b as u64).next_u64();
            VirtualBrownianTree::new(seed, dim, t0, t1, depth)
        })
        .collect()
}

/// Adaptively integrate a batch of Euclidean SDEs in parallel, one virtual
/// Brownian tree per sample (see
/// [`crate::solvers::integrate_adaptive_sde`]). Per-sample accept/reject
/// histories are independent, so outputs are bitwise-identical at any
/// `parallelism`.
pub fn batch_integrate_adaptive_par(
    vf: &dyn VectorField,
    y0s: &[Vec<f64>],
    trees: &[VirtualBrownianTree],
    h0: f64,
    ctrl: &AdaptiveController,
    parallelism: usize,
) -> Vec<AdaptiveResult> {
    let ws_pool = WorkspacePool::new();
    parallel_map(parallelism, y0s.len(), |b| {
        let mut ws = ws_pool.take();
        let tree = &trees[b];
        let res = crate::solvers::integrate_adaptive_sde_ws(
            vf,
            tree,
            tree.t0(),
            tree.t1(),
            &y0s[b],
            h0,
            ctrl,
            &mut ws,
        );
        ws_pool.put(ws);
        res
    })
}

/// [`batch_integrate_adaptive_par`] at the configured default parallelism.
pub fn batch_integrate_adaptive(
    vf: &dyn VectorField,
    y0s: &[Vec<f64>],
    trees: &[VirtualBrownianTree],
    h0: f64,
    ctrl: &AdaptiveController,
) -> Vec<AdaptiveResult> {
    batch_integrate_adaptive_par(
        vf,
        y0s,
        trees,
        h0,
        ctrl,
        crate::config::default_parallelism(),
    )
}

/// [`sample_paths_par`] at the configured default parallelism.
pub fn sample_paths(
    rng: &mut Pcg64,
    batch: usize,
    dim: usize,
    steps: usize,
    h: f64,
) -> Vec<BrownianPath> {
    sample_paths_par(rng, batch, dim, steps, h, crate::config::default_parallelism())
}

/// Integrate a batch of Euclidean SDEs in parallel, one trajectory per
/// sample, each `(steps+1) * dim` flattened (see [`crate::solvers::integrate`]).
pub fn batch_integrate_par(
    stepper: &dyn Stepper,
    vf: &dyn VectorField,
    t0: f64,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    parallelism: usize,
) -> Vec<Vec<f64>> {
    // One StepWorkspace per concurrent worker, checked out of a shared
    // pool: the per-step scratch stays warm across every sample a worker
    // integrates.
    let ws_pool = WorkspacePool::new();
    parallel_map(parallelism, y0s.len(), |b| {
        let mut ws = ws_pool.take();
        let traj = crate::solvers::integrate_ws(stepper, vf, t0, &y0s[b], &paths[b], &mut ws);
        ws_pool.put(ws);
        traj
    })
}

/// [`batch_integrate_par`] at the configured default parallelism.
pub fn batch_integrate(
    stepper: &dyn Stepper,
    vf: &dyn VectorField,
    t0: f64,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
) -> Vec<Vec<f64>> {
    batch_integrate_par(stepper, vf, t0, y0s, paths, crate::config::default_parallelism())
}

/// Batch forward+backward for a Euclidean neural SDE under a batch loss,
/// fanned out over `parallelism` workers.
/// Returns (loss, d_theta, peak adjoint memory).
///
/// Outputs are bitwise-identical for every `parallelism` (see the module
/// docs for the determinism argument).
pub fn batch_grad_euclidean_par(
    stepper: &dyn Stepper,
    method: AdjointMethod,
    vf: &dyn DiffVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
    parallelism: usize,
) -> (f64, Vec<f64>, usize) {
    batch_grad_euclidean_pool(
        stepper,
        method,
        vf,
        y0s,
        paths,
        obs,
        loss,
        parallelism,
        &WorkspacePool::new(),
    )
}

/// [`batch_grad_euclidean_par`] drawing per-worker solver scratch from a
/// **caller-owned** [`WorkspacePool`]: a long-lived loop (the trainer) hands
/// the same pool to every epoch so warm workspaces survive the epoch
/// boundary and the hot path stays allocation-free across the whole run.
/// Scratch reuse is bitwise-invisible (see
/// `rust/tests/determinism.rs::workspace_reuse_is_bitwise_invisible`).
pub fn batch_grad_euclidean_pool(
    stepper: &dyn Stepper,
    method: AdjointMethod,
    vf: &dyn DiffVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
    parallelism: usize,
    ws_pool: &WorkspacePool,
) -> (f64, Vec<f64>, usize) {
    let batch = y0s.len();
    let dim = vf.dim();
    let n_obs = obs.len();
    let steps = paths[0].steps();
    let h = paths[0].h;
    let state_size = stepper.state_size(dim);
    let seg = (steps as f64).sqrt().ceil() as usize;
    // Shared registers: current state + cotangent, the observation matrix,
    // and the aggregated parameter gradient.
    let base_mem = 2 * state_size + batch * n_obs * dim + vf.num_params();

    // ---- forward: all samples independent -------------------------------
    // Per-worker solver scratch from the caller's pool, shared between the
    // forward and backward fan-outs so the warm buffers survive the loss
    // barrier (and, for a pool owned by a training loop, the epoch
    // boundary).
    let fwd: Vec<ForwardOut> = parallel_map(parallelism, batch, |b| {
        let mut ws = ws_pool.take();
        let mut meter = MemMeter::new();
        let mut tape = MeteredTape::new();
        let mut obs_states = vec![0.0; n_obs * dim];
        let mut state = stepper.init_state(vf, 0.0, &y0s[b]);
        if method != AdjointMethod::Reversible {
            tape.push(&state, &mut meter);
        }
        let mut oi = 0;
        for n in 0..steps {
            let t = n as f64 * h;
            stepper.step_ws(vf, t, h, paths[b].increment(n), &mut state, &mut ws);
            match method {
                AdjointMethod::Full => tape.push(&state, &mut meter),
                AdjointMethod::Recursive => {
                    if (n + 1) % seg == 0 {
                        tape.push(&state, &mut meter);
                    }
                }
                AdjointMethod::Reversible => {}
            }
            while oi < n_obs && obs[oi] == n + 1 {
                obs_states[oi * dim..(oi + 1) * dim].copy_from_slice(&state[..dim]);
                oi += 1;
            }
        }
        ws_pool.put(ws);
        ForwardOut {
            final_state: state,
            tape,
            obs_states,
            retained: meter.current(),
        }
    });

    // ---- barrier: the batch loss couples samples ------------------------
    let obs_all = gather_obs(&fwd, n_obs, dim);
    let (loss_val, cots) = loss.eval_grad(&obs_all, batch, n_obs, dim);
    let tape_retained: usize = fwd.iter().map(|f| f.retained).sum();

    // ---- backward: per-sample gradients, reduced in batch order ---------
    let fwd_ref = &fwd;
    let cots_ref = &cots;
    let per_sample: Vec<(Vec<f64>, usize)> = parallel_map(parallelism, batch, |b| {
        let fw = &fwd_ref[b];
        let mut ws = ws_pool.take();
        let mut d_theta = vec![0.0; vf.num_params()];
        let mut meter = MemMeter::new(); // backward transients only
        let mut lambda = vec![0.0; state_size];
        let mut state = fw.final_state.clone();
        let mut oi = n_obs;
        let mut seg_buf = MeteredTape::new();
        for n in (0..steps).rev() {
            while oi > 0 && obs[oi - 1] == n + 1 {
                oi -= 1;
                for d in 0..dim {
                    lambda[d] += cots_ref[(b * n_obs + oi) * dim + d];
                }
            }
            let t = n as f64 * h;
            let dw = paths[b].increment(n);
            match method {
                AdjointMethod::Full => {
                    stepper.backprop_step_ws(
                        vf,
                        t,
                        h,
                        dw,
                        fw.tape.get(n),
                        &mut lambda,
                        &mut d_theta,
                        &mut ws,
                    );
                }
                AdjointMethod::Reversible => {
                    stepper.step_back_ws(vf, t, h, dw, &mut state, &mut ws);
                    stepper.backprop_step_ws(
                        vf, t, h, dw, &state, &mut lambda, &mut d_theta, &mut ws,
                    );
                }
                AdjointMethod::Recursive => {
                    if seg_buf.is_empty() {
                        let seg_start = (n / seg) * seg;
                        let ckpt_idx = n / seg;
                        let mut s = fw.tape.get(ckpt_idx).to_vec();
                        seg_buf.push(&s, &mut meter);
                        for m in seg_start..n {
                            stepper.step_ws(
                                vf,
                                m as f64 * h,
                                h,
                                paths[b].increment(m),
                                &mut s,
                                &mut ws,
                            );
                            seg_buf.push(&s, &mut meter);
                        }
                    }
                    let prev = seg_buf.pop(&mut meter).expect("segment buffer underflow");
                    stepper.backprop_step_ws(
                        vf, t, h, dw, &prev, &mut lambda, &mut d_theta, &mut ws,
                    );
                }
            }
        }
        ws_pool.put(ws);
        (d_theta, meter.peak_f64s())
    });

    let (d_theta, peak) = reduce_per_sample(&per_sample, vf.num_params(), base_mem, tape_retained);
    (loss_val, d_theta, peak)
}

/// [`batch_grad_euclidean_par`] at the configured default parallelism.
pub fn batch_grad_euclidean(
    stepper: &dyn Stepper,
    method: AdjointMethod,
    vf: &dyn DiffVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
) -> (f64, Vec<f64>, usize) {
    batch_grad_euclidean_par(
        stepper,
        method,
        vf,
        y0s,
        paths,
        obs,
        loss,
        crate::config::default_parallelism(),
    )
}

/// Batch forward+backward on a homogeneous space (Algorithm 2 per sample),
/// fanned out over `parallelism` workers.
/// Returns (loss, d_theta, peak adjoint memory); outputs are
/// bitwise-identical for every `parallelism`.
pub fn batch_grad_manifold_par(
    stepper: &dyn ManifoldStepper,
    method: AdjointMethod,
    sp: &dyn HomogeneousSpace,
    vf: &dyn DiffManifoldVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
    parallelism: usize,
) -> (f64, Vec<f64>, usize) {
    batch_grad_manifold_pool(
        stepper,
        method,
        sp,
        vf,
        y0s,
        paths,
        obs,
        loss,
        parallelism,
        &WorkspacePool::new(),
    )
}

/// [`batch_grad_manifold_par`] drawing per-worker solver scratch from a
/// **caller-owned** [`WorkspacePool`] — the manifold side of
/// [`batch_grad_euclidean_pool`], with the same warm-across-epochs purpose
/// and the same bitwise-invisibility guarantee.
pub fn batch_grad_manifold_pool(
    stepper: &dyn ManifoldStepper,
    method: AdjointMethod,
    sp: &dyn HomogeneousSpace,
    vf: &dyn DiffManifoldVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
    parallelism: usize,
    ws_pool: &WorkspacePool,
) -> (f64, Vec<f64>, usize) {
    let batch = y0s.len();
    let dim = sp.point_dim();
    let n_obs = obs.len();
    let steps = paths[0].steps();
    let h = paths[0].h;
    let seg = (steps as f64).sqrt().ceil() as usize;
    let base_mem = 2 * dim + 2 * sp.algebra_dim() + batch * n_obs * dim + vf.num_params();

    let fwd: Vec<ForwardOut> = parallel_map(parallelism, batch, |b| {
        let mut ws = ws_pool.take();
        let mut meter = MemMeter::new();
        let mut tape = MeteredTape::new();
        let mut obs_states = vec![0.0; n_obs * dim];
        let mut y = y0s[b].clone();
        if method != AdjointMethod::Reversible {
            tape.push(&y, &mut meter);
        }
        let mut oi = 0;
        for n in 0..steps {
            stepper.step_ws(sp, vf, n as f64 * h, h, paths[b].increment(n), &mut y, &mut ws);
            match method {
                AdjointMethod::Full => tape.push(&y, &mut meter),
                AdjointMethod::Recursive => {
                    if (n + 1) % seg == 0 {
                        tape.push(&y, &mut meter);
                    }
                }
                AdjointMethod::Reversible => {}
            }
            while oi < n_obs && obs[oi] == n + 1 {
                obs_states[oi * dim..(oi + 1) * dim].copy_from_slice(&y);
                oi += 1;
            }
        }
        ws_pool.put(ws);
        ForwardOut {
            final_state: y,
            tape,
            obs_states,
            retained: meter.current(),
        }
    });

    let obs_all = gather_obs(&fwd, n_obs, dim);
    let (loss_val, cots) = loss.eval_grad(&obs_all, batch, n_obs, dim);
    let tape_retained: usize = fwd.iter().map(|f| f.retained).sum();

    let fwd_ref = &fwd;
    let cots_ref = &cots;
    let per_sample: Vec<(Vec<f64>, usize)> = parallel_map(parallelism, batch, |b| {
        let fw = &fwd_ref[b];
        let mut ws = ws_pool.take();
        let mut d_theta = vec![0.0; vf.num_params()];
        let mut meter = MemMeter::new();
        let mut lambda = vec![0.0; dim];
        let mut y = fw.final_state.clone();
        let mut oi = n_obs;
        let mut seg_buf = MeteredTape::new();
        for n in (0..steps).rev() {
            while oi > 0 && obs[oi - 1] == n + 1 {
                oi -= 1;
                for d in 0..dim {
                    lambda[d] += cots_ref[(b * n_obs + oi) * dim + d];
                }
            }
            let t = n as f64 * h;
            let dw = paths[b].increment(n);
            match method {
                AdjointMethod::Full => {
                    stepper.backprop_step_ws(
                        sp,
                        vf,
                        t,
                        h,
                        dw,
                        fw.tape.get(n),
                        &mut lambda,
                        &mut d_theta,
                        &mut ws,
                    );
                }
                AdjointMethod::Reversible => {
                    stepper.step_back_ws(sp, vf, t, h, dw, &mut y, &mut ws);
                    stepper.backprop_step_ws(
                        sp, vf, t, h, dw, &y, &mut lambda, &mut d_theta, &mut ws,
                    );
                }
                AdjointMethod::Recursive => {
                    if seg_buf.is_empty() {
                        let seg_start = (n / seg) * seg;
                        let ckpt_idx = n / seg;
                        let mut s = fw.tape.get(ckpt_idx).to_vec();
                        seg_buf.push(&s, &mut meter);
                        for m in seg_start..n {
                            stepper.step_ws(
                                sp,
                                vf,
                                m as f64 * h,
                                h,
                                paths[b].increment(m),
                                &mut s,
                                &mut ws,
                            );
                            seg_buf.push(&s, &mut meter);
                        }
                    }
                    let prev = seg_buf.pop(&mut meter).expect("segment buffer underflow");
                    stepper.backprop_step_ws(
                        sp, vf, t, h, dw, &prev, &mut lambda, &mut d_theta, &mut ws,
                    );
                }
            }
        }
        ws_pool.put(ws);
        (d_theta, meter.peak_f64s())
    });

    let (d_theta, peak) = reduce_per_sample(&per_sample, vf.num_params(), base_mem, tape_retained);
    (loss_val, d_theta, peak)
}

/// [`batch_grad_manifold_par`] at the configured default parallelism.
pub fn batch_grad_manifold(
    stepper: &dyn ManifoldStepper,
    method: AdjointMethod,
    sp: &dyn HomogeneousSpace,
    vf: &dyn DiffManifoldVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
) -> (f64, Vec<f64>, usize) {
    batch_grad_manifold_par(
        stepper,
        method,
        sp,
        vf,
        y0s,
        paths,
        obs,
        loss,
        crate::config::default_parallelism(),
    )
}

/// Generic Euclidean training loop — **deprecated**: the epoch loop now
/// lives in the training engine ([`crate::train::Trainer`] +
/// [`crate::train::EuclideanProblem`]), which adds schedules, callbacks,
/// checkpointing and gradient accumulation on top of the identical
/// arithmetic. This wrapper drives the engine on the caller's optimiser
/// state (so existing call sites behave bit-for-bit as before) and remains
/// for one release.
#[deprecated(
    since = "0.2.0",
    note = "use train::Trainer with train::EuclideanProblem (see docs/ARCHITECTURE.md §Training engine)"
)]
pub fn train_euclidean<M, FGet, FSet>(
    model: &mut M,
    get_params: FGet,
    set_params: FSet,
    stepper: &dyn Stepper,
    method: AdjointMethod,
    sample_batch: &mut dyn FnMut(&mut Pcg64) -> (Vec<Vec<f64>>, Vec<BrownianPath>),
    obs: &[usize],
    loss: &dyn BatchLoss,
    opt: &mut Optimizer,
    epochs: usize,
    clip: Option<f64>,
    rng: &mut Pcg64,
) -> TrainLog
where
    M: DiffVectorField,
    FGet: Fn(&M) -> Vec<f64>,
    FSet: Fn(&mut M, &[f64]),
{
    /// Closure-based shim: adapts the legacy (model, get, set, sampler)
    /// calling convention onto [`TrainProblem`].
    struct Shim<'a, M, FGet, FSet> {
        model: &'a mut M,
        get: FGet,
        set: FSet,
        stepper: &'a dyn Stepper,
        method: AdjointMethod,
        sampler: &'a mut dyn FnMut(&mut Pcg64) -> (Vec<Vec<f64>>, Vec<BrownianPath>),
        obs: &'a [usize],
        loss: &'a dyn BatchLoss,
        pool: WorkspacePool,
    }

    impl<M, FGet, FSet> TrainProblem for Shim<'_, M, FGet, FSet>
    where
        M: DiffVectorField,
        FGet: Fn(&M) -> Vec<f64>,
        FSet: Fn(&mut M, &[f64]),
    {
        fn num_params(&self) -> usize {
            self.model.num_params()
        }
        fn params(&self) -> Vec<f64> {
            (self.get)(&*self.model)
        }
        fn set_params(&mut self, p: &[f64]) {
            (self.set)(&mut *self.model, p)
        }
        fn grad(
            &mut self,
            _epoch: usize,
            rng: &mut Pcg64,
            parallelism: usize,
        ) -> (f64, Vec<f64>, usize) {
            let (y0s, paths) = (self.sampler)(rng);
            batch_grad_euclidean_pool(
                self.stepper,
                self.method,
                &*self.model,
                &y0s,
                &paths,
                self.obs,
                self.loss,
                parallelism,
                &self.pool,
            )
        }
    }

    let mut shim = Shim {
        model,
        get: get_params,
        set: set_params,
        stepper,
        method,
        sampler: sample_batch,
        obs,
        loss,
        pool: WorkspacePool::new(),
    };
    let trainer = Trainer::new(TrainConfig::new(epochs).group(OptimSpec::of(opt), clip));
    // Run on the caller's optimiser state, then hand the advanced state
    // back (the legacy contract: `opt` is mutated in place).
    let mut opts = vec![opt.clone()];
    let log = trainer.run_resumed(&mut shim, rng, &mut [], &mut opts);
    *opt = opts.remove(0);
    log
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::MomentMatch;
    use crate::models::ou::OuParams;
    use crate::nn::neural_sde::NeuralSde;
    use crate::solvers::LowStorageStepper;

    /// End-to-end smoke through the deprecated legacy wrapper: a tiny
    /// neural SDE trained on OU moments with the reversible adjoint reduces
    /// the loss, and the wrapper is **bitwise-identical** to driving
    /// [`crate::train::Trainer`] directly (the one-training-path contract
    /// of the deprecation period).
    #[test]
    #[allow(deprecated)]
    fn training_reduces_loss_on_ou() {
        let mut rng = Pcg64::new(20);
        let ou = OuParams::default();
        let steps = 16;
        let h = 2.0 / steps as f64;
        let obs: Vec<usize> = (4..=steps).step_by(4).collect();
        // Exact-moment targets at the observation times.
        let (mean_all, m2_all) = ou.moment_targets(0.0, steps, h, 4000, &mut rng);
        let target_mean: Vec<f64> = obs.iter().map(|&i| mean_all[i]).collect();
        let target_m2: Vec<f64> = obs.iter().map(|&i| m2_all[i]).collect();
        let loss = MomentMatch {
            target_mean,
            target_m2,
        };
        let mut model = NeuralSde::lsde(1, 8, 1, true, &mut rng);
        let st = LowStorageStepper::ees25();
        let mut opt = Optimizer::adam(0.02, model.num_params());
        let batch = 64;
        let mut sampler = move |rng: &mut Pcg64| {
            let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.0]).collect();
            let paths: Vec<BrownianPath> = (0..batch)
                .map(|_| BrownianPath::sample(rng, 1, steps, h))
                .collect();
            (y0s, paths)
        };
        let log = train_euclidean(
            &mut model,
            |m: &NeuralSde| m.params(),
            |m: &mut NeuralSde, p: &[f64]| m.set_params(p),
            &st,
            AdjointMethod::Reversible,
            &mut sampler,
            &obs,
            &loss,
            &mut opt,
            40,
            Some(1.0),
            &mut rng,
        );
        let first: f64 = log.history[..5].iter().map(|m| m.loss).sum::<f64>() / 5.0;
        let last: f64 = log.history[35..].iter().map(|m| m.loss).sum::<f64>() / 5.0;
        assert!(
            last < 0.7 * first,
            "loss must decrease: {first} -> {last}"
        );

        // The same run driven through the training engine directly must be
        // bitwise-identical — the wrapper is a shim, not a second path.
        let mut rng2 = Pcg64::new(20);
        let (mean_all2, m2_all2) = ou.moment_targets(0.0, steps, h, 4000, &mut rng2);
        let loss2 = MomentMatch {
            target_mean: obs.iter().map(|&i| mean_all2[i]).collect(),
            target_m2: obs.iter().map(|&i| m2_all2[i]).collect(),
        };
        let model2 = NeuralSde::lsde(1, 8, 1, true, &mut rng2);
        let sampler2 = move |rng: &mut Pcg64| {
            let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.0]).collect();
            let paths: Vec<BrownianPath> = (0..batch)
                .map(|_| BrownianPath::sample(rng, 1, steps, h))
                .collect();
            (y0s, paths)
        };
        let mut problem = crate::train::EuclideanProblem::new(
            model2,
            &st,
            AdjointMethod::Reversible,
            sampler2,
            obs.clone(),
            &loss2,
        );
        let trainer = Trainer::new(
            TrainConfig::new(40).group(OptimSpec::Adam { lr: 0.02 }, Some(1.0)),
        );
        let log2 = trainer.run(&mut problem, &mut rng2);
        assert_eq!(log.history.len(), log2.history.len());
        for (a, b) in log.history.iter().zip(log2.history.iter()) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "epoch {}", a.epoch);
        }
        for (a, b) in model
            .params()
            .iter()
            .zip(crate::train::FlatParams::params(&problem.model).iter())
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// Batch gradients agree across adjoints (Table-12 property at batch level).
    #[test]
    fn batch_adjoints_agree() {
        let mut rng = Pcg64::new(21);
        let model = NeuralSde::lsde(2, 6, 1, false, &mut rng);
        let st = LowStorageStepper::ees25();
        let steps = 20;
        let h = 0.05;
        let batch = 4;
        let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.1, -0.1]).collect();
        let paths: Vec<BrownianPath> = (0..batch)
            .map(|_| BrownianPath::sample(&mut rng, 2, steps, h))
            .collect();
        let obs = vec![10, 20];
        let mut data = vec![0.0; batch * 2 * 2];
        rng.fill_normal(&mut data);
        let loss = MomentMatch::from_data(&data, batch, 2, 2);
        let (l0, g0, m_full) = batch_grad_euclidean(
            &st,
            AdjointMethod::Full,
            &model,
            &y0s,
            &paths,
            &obs,
            &loss,
        );
        for method in [AdjointMethod::Recursive, AdjointMethod::Reversible] {
            let (l, g, m) =
                batch_grad_euclidean(&st, method, &model, &y0s, &paths, &obs, &loss);
            assert!((l - l0).abs() < 1e-10);
            for (a, b) in g.iter().zip(g0.iter()) {
                assert!((a - b).abs() < 1e-7 * (1.0 + b.abs()), "{a} vs {b}");
            }
            assert!(m < m_full, "{} must use less memory", method.name());
        }
    }

    /// The engine's central contract: every worker count yields bit-equal
    /// losses, gradients and memory figures.
    #[test]
    fn parallelism_is_bitwise_invisible() {
        let mut rng = Pcg64::new(33);
        let model = NeuralSde::lsde(3, 8, 1, false, &mut rng);
        let st = LowStorageStepper::ees25();
        let (steps, h, batch) = (12, 0.05, 7);
        let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.2, 0.0, -0.1]).collect();
        let paths = sample_paths_par(&mut rng, batch, 3, steps, h, 3);
        let obs = vec![6, 12];
        let mut data = vec![0.0; batch * 2 * 3];
        rng.fill_normal(&mut data);
        let loss = MomentMatch::from_data(&data, batch, 2, 3);
        for method in [
            AdjointMethod::Full,
            AdjointMethod::Recursive,
            AdjointMethod::Reversible,
        ] {
            let (l1, g1, m1) = batch_grad_euclidean_par(
                &st, method, &model, &y0s, &paths, &obs, &loss, 1,
            );
            for p in [2, 4, 16] {
                let (lp, gp, mp) = batch_grad_euclidean_par(
                    &st, method, &model, &y0s, &paths, &obs, &loss, p,
                );
                assert_eq!(l1.to_bits(), lp.to_bits(), "{} p={p}", method.name());
                assert_eq!(m1, mp, "{} p={p}", method.name());
                for (a, b) in g1.iter().zip(gp.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{} p={p}", method.name());
                }
            }
        }
    }

    /// Adaptive batch solves over per-sample virtual Brownian trees are
    /// bitwise worker-count-invariant, including the accept/reject
    /// histories.
    #[test]
    fn adaptive_batch_bitwise_invariant_in_parallelism() {
        let mut rng = Pcg64::new(55);
        let model = NeuralSde::lsde(2, 6, 2, false, &mut rng);
        let batch = 6;
        let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.2, -0.1]).collect();
        let trees = {
            let mut root = Pcg64::new(77);
            sample_trees(&mut root, batch, 2, 0.0, 1.0, 16)
        };
        let ctrl = AdaptiveController::default();
        let base = batch_integrate_adaptive_par(&model, &y0s, &trees, 0.1, &ctrl, 1);
        for p in [2, 4, 8] {
            let run = batch_integrate_adaptive_par(&model, &y0s, &trees, 0.1, &ctrl, p);
            for (a, b) in base.iter().zip(run.iter()) {
                assert_eq!(a.steps_accepted, b.steps_accepted, "P={p}");
                assert_eq!(a.steps_rejected, b.steps_rejected, "P={p}");
                for (x, y) in a.y.iter().zip(b.y.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "P={p}");
                }
            }
        }
        // Distinct samples see distinct noise: terminal states differ.
        assert_ne!(base[0].y, base[1].y);
    }

    /// Split-stream path sampling is parallelism-invariant and per-sample
    /// independent.
    #[test]
    fn sample_paths_split_streams_deterministic() {
        let paths_at = |p: usize| {
            let mut rng = Pcg64::new(77);
            sample_paths_par(&mut rng, 5, 2, 8, 0.1, p)
        };
        let a = paths_at(1);
        let b = paths_at(4);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.dw, y.dw);
        }
        // Distinct samples see distinct noise.
        assert_ne!(a[0].dw, a[1].dw);
    }
}
