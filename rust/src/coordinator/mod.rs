//! Batch-solve coordinator: the L3 orchestration layer under the training
//! engine.
//!
//! Owns batch trajectory generation over per-sample Brownian drivers,
//! batch-loss evaluation, per-sample backward sweeps through the chosen
//! adjoint, and the deterministic gradient reduction — the primitives that
//! [`crate::train::Trainer`] (the training engine that owns every
//! experiment's epoch loop, optimisers, schedules and callbacks) drives
//! once per epoch. Python is never on this path; the compiled-artifact mode
//! executes the AOT JAX/Pallas step function through [`crate::runtime`]
//! instead of the native field.
//!
//! # Parallel batch engine
//!
//! Batch samples are embarrassingly parallel: each trajectory owns its
//! driver, tape and cotangent. The forward sweep and the backward sweep each
//! fan out through [`parallel::parallel_map`]; the batch loss (which
//! genuinely couples samples) is the only sequential barrier between them.
//! Results are **bitwise-deterministic in the worker count**:
//!
//! - per-sample state never crosses threads mid-computation;
//! - the parameter gradient is reduced per sample first, then summed in
//!   fixed batch order;
//! - per-sample noise comes from independent [`Pcg64::split`] streams (see
//!   [`sample_paths_par`]), not from interleaved draws on a shared stream.
//!
//! The worker count comes from the call site (`*_par` variants) or, for the
//! plain-named wrappers, from [`crate::config::default_parallelism`] (the
//! `EES_PARALLELISM` env var, else all available cores). A config-driven
//! harness that parses an `[exec] parallelism` key
//! ([`crate::config::Config::parallelism`]) must hand the value to a
//! `*_par` entry point explicitly.
//!
//! # Lane-blocked hot path
//!
//! Workers claim **lane groups** rather than single samples: a group of
//! `L` samples is stepped together in structure-of-arrays (lane-major)
//! layout through the stepper's `*_lanes_ws` entry points, so every solver
//! stage evaluates the vector field as one `(L × d)` blocked matmul
//! ([`crate::linalg::matmul_lanes`]) instead of `L` separate matvecs —
//! forward, reversible `step_back`, and the whole adjoint sweep. The lane
//! width defaults to [`crate::config::default_lanes`] (the `EES_LANES` env
//! var / `[exec] lanes` key, capped at [`crate::linalg::MAX_LANES`]) and
//! can be set per call via the `*_lanes` entry points; grouping only
//! engages when BOTH the stepper and the field carry lane-blocked
//! implementations ([`Stepper::lane_blocked`] /
//! [`VectorField::lane_blocked`]), everything else falls back to
//! per-sample stepping.
//!
//! Lane grouping is **bitwise-invisible**: the lane kernels reduce along
//! the contraction dimension in exactly the per-sample [`crate::linalg::dot`]
//! order, per-sample tapes/meters/noise are preserved inside the group, and
//! per-lane parameter cotangents are reduced in fixed batch order — so
//! loss, gradient and memory figures are identical at every `(workers,
//! lanes)` combination (pinned by `rust/tests/determinism.rs`).
//!
//! When the crate is built with `--features simd`, the lane kernels the
//! group step runs on ([`crate::linalg::matmul_lanes`] and the
//! [`crate::nn::Mlp`] lane epilogues) additionally consult the process-wide
//! SIMD toggle ([`crate::linalg::simd_enabled`]: the `EES_SIMD` env var /
//! `[exec] simd` key, applied process-wide via [`crate::linalg::set_simd`]
//! once at scenario setup).
//! No batch entry point takes a SIMD parameter — the knob is resolved
//! inside the kernels so every caller (pool, lanes, manifold) inherits it
//! uniformly; see `docs/ARCHITECTURE.md` §SIMD kernels & the determinism
//! contract for why the portable arm stays bitwise-equal.
//!
//! # Memory accounting
//!
//! The adjoint-memory model meters the same quantities as a sequential
//! sweep would: `peak = shared registers + Σ_b retained tape + max_b
//! backward transient` — all tapes coexist after the forward pass, while
//! backward segment buffers are transient per sample. The formula is
//! deterministic in the worker count; per-sample gradient scratch (an
//! artifact of the parallel reduction, `min(workers, batch) · |θ|`) is
//! deliberately excluded, exactly as the sequential meter excluded its
//! single shared accumulator's duplicates.

pub mod parallel;

pub use parallel::parallel_map;

// The per-epoch metric types moved into the training engine when the epoch
// loop did (`crate::train`); these re-exports keep pre-move paths working.
pub use crate::train::{EpochMetrics, TrainLog};

use crate::adjoint::AdjointMethod;
use crate::lie::HomogeneousSpace;
use crate::losses::BatchLoss;
use crate::memory::{MemMeter, MeteredTape, WorkspacePool};
use crate::rng::{BrownianPath, BrownianSource, Pcg64, VirtualBrownianTree};
use crate::solvers::{AdaptiveController, AdaptiveResult, ManifoldStepper, Stepper};
use crate::vf::{DiffManifoldVectorField, DiffVectorField, VectorField};

/// Per-sample output of the forward sweep (tape + observations + terminal
/// solver state), kept alive until the sample's backward sweep consumes it.
struct ForwardOut {
    final_state: Vec<f64>,
    tape: MeteredTape,
    obs_states: Vec<f64>,
    /// f64 slots retained by the tape after the forward pass.
    retained: usize,
}

/// Assemble the batch observation matrix from per-sample forward outputs,
/// in fixed batch order (part of the determinism contract).
fn gather_obs(fwd: &[ForwardOut], n_obs: usize, dim: usize) -> Vec<f64> {
    let mut obs_all = vec![0.0; fwd.len() * n_obs * dim];
    for (b, f) in fwd.iter().enumerate() {
        obs_all[b * n_obs * dim..(b + 1) * n_obs * dim].copy_from_slice(&f.obs_states);
    }
    obs_all
}

/// Reduce per-sample (gradient, backward transient peak) pairs in fixed
/// batch order and apply the shared memory model
/// `base + Σ retained + max transient` — the single source of truth for
/// both the Euclidean and manifold engines (see the module docs).
fn reduce_per_sample(
    per_sample: &[(Vec<f64>, usize)],
    num_params: usize,
    base_mem: usize,
    tape_retained: usize,
) -> (Vec<f64>, usize) {
    let mut d_theta = vec![0.0; num_params];
    let mut backward_peak = 0usize;
    for (g, peak) in per_sample {
        for (acc, v) in d_theta.iter_mut().zip(g.iter()) {
            *acc += v;
        }
        backward_peak = backward_peak.max(*peak);
    }
    (d_theta, base_mem + tape_retained + backward_peak)
}

/// Resolve the lane-group width a batch call actually steps with: the
/// request clamped to `1..=`[`crate::linalg::MAX_LANES`], forced to 1
/// unless BOTH the stepper and the vector field carry lane-blocked
/// implementations ([`Stepper::lane_blocked`] /
/// [`VectorField::lane_blocked`]) — grouping per-lane fallbacks adds
/// gather/scatter work with no blocking win.
fn effective_lanes(stepper: &dyn Stepper, vf: &dyn VectorField, lanes: usize) -> usize {
    if stepper.lane_blocked() && vf.lane_blocked() {
        lanes.clamp(1, crate::linalg::MAX_LANES)
    } else {
        1
    }
}

/// [`effective_lanes`] for the manifold engine: grouping engages only when
/// BOTH the [`ManifoldStepper`] and the [`crate::vf::ManifoldVectorField`]
/// carry lane-blocked implementations.
fn effective_lanes_manifold(
    stepper: &dyn ManifoldStepper,
    vf: &dyn DiffManifoldVectorField,
    lanes: usize,
) -> usize {
    if stepper.lane_blocked() && vf.lane_blocked() {
        lanes.clamp(1, crate::linalg::MAX_LANES)
    } else {
        1
    }
}

/// Pack step `n`'s per-sample driver increments for the lane group
/// `[lo, lo + ll)` into a lane-major `noise_dim × ll` block.
fn pack_noise(paths: &[BrownianPath], lo: usize, ll: usize, n: usize, dw: &mut [f64]) {
    let nd = dw.len() / ll;
    for l in 0..ll {
        let inc = paths[lo + l].increment(n);
        for (j, v) in inc.iter().enumerate().take(nd) {
            dw[j * ll + l] = *v;
        }
    }
}

/// Sample `batch` independent Brownian drivers from per-sample
/// [`Pcg64::split`] streams, generating paths in parallel.
///
/// The per-sample streams are derived **sequentially, in index order, on
/// the calling thread** before any parallel work starts (`split` advances
/// the parent generator, so split order matters — a stream is a function of
/// the parent state *at the time of the split*, not of the index alone).
/// Only the path generation from the already-derived streams fans out,
/// which is why the batch is identical for every `parallelism`.
pub fn sample_paths_par(
    rng: &mut Pcg64,
    batch: usize,
    dim: usize,
    steps: usize,
    h: f64,
    parallelism: usize,
) -> Vec<BrownianPath> {
    let streams: Vec<Pcg64> = (0..batch).map(|b| rng.split(b as u64)).collect();
    parallel_map(parallelism, batch, |b| {
        let mut s = streams[b].clone();
        BrownianPath::sample(&mut s, dim, steps, h)
    })
}

/// Derive `batch` independent [`VirtualBrownianTree`]s over [t0, t1] from
/// per-sample [`Pcg64::split`] streams — the tree analogue of
/// [`sample_paths_par`].
///
/// Seeds are derived **sequentially, in index order, on the calling
/// thread** (the same contract as path sampling: `split` advances the
/// parent generator, so split order is part of the determinism story). The
/// trees themselves are stateless, so no parallel phase is needed at all:
/// handing tree `b` to any worker yields bitwise-identical queries at any
/// worker count.
pub fn sample_trees(
    rng: &mut Pcg64,
    batch: usize,
    dim: usize,
    t0: f64,
    t1: f64,
    depth: u32,
) -> Vec<VirtualBrownianTree> {
    (0..batch)
        .map(|b| {
            let seed = rng.split(b as u64).next_u64();
            VirtualBrownianTree::new(seed, dim, t0, t1, depth)
        })
        .collect()
}

/// Adaptively integrate a batch of Euclidean SDEs in parallel, one virtual
/// Brownian tree per sample (see
/// [`crate::solvers::integrate_adaptive_sde`]). Per-sample accept/reject
/// histories are independent, so outputs are bitwise-identical at any
/// `parallelism`.
pub fn batch_integrate_adaptive_par(
    vf: &dyn VectorField,
    y0s: &[Vec<f64>],
    trees: &[VirtualBrownianTree],
    h0: f64,
    ctrl: &AdaptiveController,
    parallelism: usize,
) -> Vec<AdaptiveResult> {
    let ws_pool = WorkspacePool::new();
    parallel_map(parallelism, y0s.len(), |b| {
        let mut ws = ws_pool.take();
        let tree = &trees[b];
        let res = crate::solvers::integrate_adaptive_sde_ws(
            vf,
            tree,
            tree.t0(),
            tree.t1(),
            &y0s[b],
            h0,
            ctrl,
            &mut ws,
        );
        ws_pool.put(ws);
        res
    })
}

/// [`batch_integrate_adaptive_par`] at the configured default parallelism.
pub fn batch_integrate_adaptive(
    vf: &dyn VectorField,
    y0s: &[Vec<f64>],
    trees: &[VirtualBrownianTree],
    h0: f64,
    ctrl: &AdaptiveController,
) -> Vec<AdaptiveResult> {
    batch_integrate_adaptive_par(
        vf,
        y0s,
        trees,
        h0,
        ctrl,
        crate::config::default_parallelism(),
    )
}

/// [`sample_paths_par`] at the configured default parallelism.
pub fn sample_paths(
    rng: &mut Pcg64,
    batch: usize,
    dim: usize,
    steps: usize,
    h: f64,
) -> Vec<BrownianPath> {
    sample_paths_par(rng, batch, dim, steps, h, crate::config::default_parallelism())
}

/// Integrate a batch of Euclidean SDEs in parallel, one trajectory per
/// sample, each `(steps+1) * dim` flattened (see [`crate::solvers::integrate`]).
///
/// Workers claim **lane groups** (width [`crate::config::default_lanes`],
/// override via [`batch_integrate_lanes_par`]) rather than single samples:
/// a lane-blocked stepper advances the whole group per stage in
/// structure-of-arrays layout, turning per-sample matvecs into blocked
/// matmuls. Trajectories are bitwise-identical at every worker AND lane
/// count (pinned by `rust/tests/determinism.rs`).
pub fn batch_integrate_par(
    stepper: &dyn Stepper,
    vf: &dyn VectorField,
    t0: f64,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    parallelism: usize,
) -> Vec<Vec<f64>> {
    batch_integrate_lanes_par(
        stepper,
        vf,
        t0,
        y0s,
        paths,
        parallelism,
        crate::config::default_lanes(),
    )
}

/// [`batch_integrate_par`] with an explicit lane-group width (1 =
/// per-sample stepping; clamped to [`crate::linalg::MAX_LANES`]; forced to
/// 1 unless both the stepper and the field are lane-blocked). A lane
/// group steps one shared `(t, h)` grid, so grouping additionally
/// requires every path on the same uniform grid — a batch with
/// heterogeneous step counts or step sizes (legal here since PR 1) falls
/// back to per-sample integration, each trajectory on its own grid.
pub fn batch_integrate_lanes_par(
    stepper: &dyn Stepper,
    vf: &dyn VectorField,
    t0: f64,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    parallelism: usize,
    lanes: usize,
) -> Vec<Vec<f64>> {
    let batch = y0s.len();
    let lanes = effective_lanes(stepper, vf, lanes);
    let uniform_grid = paths
        .windows(2)
        .all(|w| w[0].steps() == w[1].steps() && w[0].h == w[1].h);
    if lanes <= 1 || !uniform_grid {
        // One StepWorkspace per concurrent worker, checked out of a shared
        // pool: the per-step scratch stays warm across every sample a
        // worker integrates.
        let ws_pool = WorkspacePool::new();
        return parallel_map(parallelism, batch, |b| {
            let mut ws = ws_pool.take();
            let traj = crate::solvers::integrate_ws(stepper, vf, t0, &y0s[b], &paths[b], &mut ws);
            ws_pool.put(ws);
            traj
        });
    }
    let dim = vf.dim();
    let state_size = stepper.state_size(dim);
    // (batch + lanes - 1) / lanes, spelled out: the crate pins
    // rust-version 1.70, before usize::div_ceil stabilised.
    let groups = (batch + lanes - 1) / lanes;
    let ws_pool = WorkspacePool::new();
    let per_group: Vec<Vec<Vec<f64>>> = parallel_map(parallelism, groups, |g| {
        let lo = g * lanes;
        let ll = lanes.min(batch - lo);
        let steps = paths[lo].steps();
        let h = paths[lo].h;
        let mut ws = ws_pool.take();
        let mut state = ws.take(state_size * ll);
        for l in 0..ll {
            let s = stepper.init_state(vf, t0, &y0s[lo + l]);
            crate::linalg::lane_scatter(&s, l, ll, &mut state);
        }
        let mut dw = ws.take(vf.noise_dim() * ll);
        let mut trajs: Vec<Vec<f64>> = (lo..lo + ll)
            .map(|b| {
                let mut t = vec![0.0; (steps + 1) * dim];
                t[..dim].copy_from_slice(&y0s[b]);
                t
            })
            .collect();
        for n in 0..steps {
            let t = t0 + n as f64 * h;
            pack_noise(paths, lo, ll, n, &mut dw);
            stepper.step_lanes_ws(vf, t, h, &dw, &mut state, ll, &mut ws);
            for (l, traj) in trajs.iter_mut().enumerate() {
                for d in 0..dim {
                    traj[(n + 1) * dim + d] = state[d * ll + l];
                }
            }
        }
        ws.put(dw);
        ws.put(state);
        ws_pool.put(ws);
        trajs
    });
    per_group.into_iter().flatten().collect()
}

/// [`batch_integrate_lanes_par`] keeping only the terminal states — the
/// streaming entry point the risk engine sweeps millions of paths through.
///
/// No trajectory is materialised: memory is O(state × lanes) per worker
/// regardless of the step count, and each returned `Vec` is the final
/// `dim`-vector of its sample. Step order, lane packing and workspace use
/// mirror [`batch_integrate_lanes_par`] float-op for float-op, so terminals
/// are bitwise-identical to the last trajectory row of the full integration
/// at every `(parallelism, lanes)` combination (pinned by
/// `rust/tests/determinism.rs`).
pub fn batch_terminal_lanes_par(
    stepper: &dyn Stepper,
    vf: &dyn VectorField,
    t0: f64,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    parallelism: usize,
    lanes: usize,
) -> Vec<Vec<f64>> {
    batch_terminal_lanes_pool(
        stepper,
        vf,
        t0,
        y0s,
        paths,
        parallelism,
        lanes,
        &WorkspacePool::new(),
    )
}

/// [`batch_terminal_lanes_par`] drawing scratch from a **caller-owned**
/// [`WorkspacePool`]: a long-lived loop (the serving workers in
/// `crate::serve`) hands in a warm pool so steady-state dispatch allocates
/// nothing. The pool is only a scratch source — outputs are bitwise
/// those of [`batch_terminal_lanes_par`].
#[allow(clippy::too_many_arguments)]
pub fn batch_terminal_lanes_pool(
    stepper: &dyn Stepper,
    vf: &dyn VectorField,
    t0: f64,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    parallelism: usize,
    lanes: usize,
    ws_pool: &WorkspacePool,
) -> Vec<Vec<f64>> {
    let batch = y0s.len();
    let lanes = effective_lanes(stepper, vf, lanes);
    let uniform_grid = paths
        .windows(2)
        .all(|w| w[0].steps() == w[1].steps() && w[0].h == w[1].h);
    let dim = vf.dim();
    if lanes <= 1 || !uniform_grid {
        return parallel_map(parallelism, batch, |b| {
            let mut ws = ws_pool.take();
            let mut state = stepper.init_state(vf, t0, &y0s[b]);
            for n in 0..paths[b].steps() {
                let t = t0 + n as f64 * paths[b].h;
                stepper.step_ws(vf, t, paths[b].h, paths[b].increment(n), &mut state, &mut ws);
            }
            ws_pool.put(ws);
            state.truncate(dim);
            state
        });
    }
    let state_size = stepper.state_size(dim);
    // (batch + lanes - 1) / lanes, spelled out: the crate pins
    // rust-version 1.70, before usize::div_ceil stabilised.
    let groups = (batch + lanes - 1) / lanes;
    let per_group: Vec<Vec<Vec<f64>>> = parallel_map(parallelism, groups, |g| {
        let lo = g * lanes;
        let ll = lanes.min(batch - lo);
        let steps = paths[lo].steps();
        let h = paths[lo].h;
        let mut ws = ws_pool.take();
        let mut state = ws.take(state_size * ll);
        for l in 0..ll {
            let s = stepper.init_state(vf, t0, &y0s[lo + l]);
            crate::linalg::lane_scatter(&s, l, ll, &mut state);
        }
        let mut dw = ws.take(vf.noise_dim() * ll);
        for n in 0..steps {
            let t = t0 + n as f64 * h;
            pack_noise(paths, lo, ll, n, &mut dw);
            stepper.step_lanes_ws(vf, t, h, &dw, &mut state, ll, &mut ws);
        }
        let terminals: Vec<Vec<f64>> = (0..ll)
            .map(|l| (0..dim).map(|d| state[d * ll + l]).collect())
            .collect();
        ws.put(dw);
        ws.put(state);
        ws_pool.put(ws);
        terminals
    });
    per_group.into_iter().flatten().collect()
}

/// [`batch_integrate_par`] at the configured default parallelism.
pub fn batch_integrate(
    stepper: &dyn Stepper,
    vf: &dyn VectorField,
    t0: f64,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
) -> Vec<Vec<f64>> {
    batch_integrate_par(stepper, vf, t0, y0s, paths, crate::config::default_parallelism())
}

/// Batch forward+backward for a Euclidean neural SDE under a batch loss,
/// fanned out over `parallelism` workers.
/// Returns (loss, d_theta, peak adjoint memory).
///
/// Outputs are bitwise-identical for every `parallelism` (see the module
/// docs for the determinism argument).
pub fn batch_grad_euclidean_par(
    stepper: &dyn Stepper,
    method: AdjointMethod,
    vf: &dyn DiffVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
    parallelism: usize,
) -> (f64, Vec<f64>, usize) {
    batch_grad_euclidean_pool(
        stepper,
        method,
        vf,
        y0s,
        paths,
        obs,
        loss,
        parallelism,
        &WorkspacePool::new(),
    )
}

/// [`batch_grad_euclidean_par`] drawing per-worker solver scratch from a
/// **caller-owned** [`WorkspacePool`]: a long-lived loop (the trainer) hands
/// the same pool to every epoch so warm workspaces survive the epoch
/// boundary and the hot path stays allocation-free across the whole run.
/// Scratch reuse is bitwise-invisible (see
/// `rust/tests/determinism.rs::workspace_reuse_is_bitwise_invisible`).
///
/// Workers claim **lane groups** of [`crate::config::default_lanes`]
/// samples (override via [`batch_grad_euclidean_pool_lanes`]) and step the
/// whole group per stage in structure-of-arrays layout — the lane-blocked
/// hot path. Results are bitwise-identical at every lane count.
#[allow(clippy::too_many_arguments)]
pub fn batch_grad_euclidean_pool(
    stepper: &dyn Stepper,
    method: AdjointMethod,
    vf: &dyn DiffVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
    parallelism: usize,
    ws_pool: &WorkspacePool,
) -> (f64, Vec<f64>, usize) {
    batch_grad_euclidean_pool_lanes(
        stepper,
        method,
        vf,
        y0s,
        paths,
        obs,
        loss,
        parallelism,
        ws_pool,
        crate::config::default_lanes(),
    )
}

/// [`batch_grad_euclidean_pool`] with an explicit lane-group width.
///
/// `lanes = 1` runs the per-sample engine; `lanes = L > 1` steps groups of
/// `L` samples at once through the stepper's `*_lanes_ws` entry points
/// (forward, reversible `step_back`, and the whole adjoint sweep), so every
/// solver stage evaluates the vector field as an `(L × d)` blocked matmul
/// instead of `L` separate matvecs. Per-sample noise streams, per-sample
/// tapes/memory meters, and the fixed-batch-order gradient reduction are
/// all preserved, so loss, gradient and memory figures are
/// **bitwise-identical at every worker AND lane count** (pinned by
/// `rust/tests/determinism.rs`). Stepper/field pairs without lane-blocked
/// implementations fall back to `lanes = 1`.
#[allow(clippy::too_many_arguments)]
pub fn batch_grad_euclidean_pool_lanes(
    stepper: &dyn Stepper,
    method: AdjointMethod,
    vf: &dyn DiffVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
    parallelism: usize,
    ws_pool: &WorkspacePool,
    lanes: usize,
) -> (f64, Vec<f64>, usize) {
    let lanes = effective_lanes(stepper, vf, lanes);
    if lanes <= 1 {
        return batch_grad_euclidean_scalar(
            stepper, method, vf, y0s, paths, obs, loss, parallelism, ws_pool,
        );
    }
    let batch = y0s.len();
    let dim = vf.dim();
    let noise_dim = vf.noise_dim();
    let np = vf.num_params();
    let n_obs = obs.len();
    let steps = paths[0].steps();
    let h = paths[0].h;
    let state_size = stepper.state_size(dim);
    let seg = (steps as f64).sqrt().ceil() as usize;
    let base_mem = 2 * state_size + batch * n_obs * dim + np;
    let groups = (batch + lanes - 1) / lanes;

    // ---- forward: lane groups independent -------------------------------
    // Per-sample tapes, memory meters and observation rows survive inside
    // the group, so the adjoint-memory model meters exactly what the
    // per-sample engine meters.
    let fwd_groups: Vec<Vec<ForwardOut>> = parallel_map(parallelism, groups, |g| {
        let lo = g * lanes;
        let ll = lanes.min(batch - lo);
        let mut ws = ws_pool.take();
        let mut meters: Vec<MemMeter> = (0..ll).map(|_| MemMeter::new()).collect();
        let mut tapes: Vec<MeteredTape> = (0..ll).map(|_| MeteredTape::new()).collect();
        let mut obs_states: Vec<Vec<f64>> = (0..ll).map(|_| vec![0.0; n_obs * dim]).collect();
        let mut state = ws.take(state_size * ll);
        for l in 0..ll {
            let s = stepper.init_state(vf, 0.0, &y0s[lo + l]);
            crate::linalg::lane_scatter(&s, l, ll, &mut state);
            if method != AdjointMethod::Reversible {
                tapes[l].push(&s, &mut meters[l]);
            }
        }
        let mut dw = ws.take(noise_dim * ll);
        let mut tmp = ws.take(state_size);
        let mut oi = 0;
        for n in 0..steps {
            let t = n as f64 * h;
            pack_noise(paths, lo, ll, n, &mut dw);
            stepper.step_lanes_ws(vf, t, h, &dw, &mut state, ll, &mut ws);
            let record = match method {
                AdjointMethod::Full => true,
                AdjointMethod::Recursive => (n + 1) % seg == 0,
                AdjointMethod::Reversible => false,
            };
            if record {
                for l in 0..ll {
                    crate::linalg::lane_gather(&state, l, ll, &mut tmp);
                    tapes[l].push(&tmp, &mut meters[l]);
                }
            }
            while oi < n_obs && obs[oi] == n + 1 {
                for (l, os) in obs_states.iter_mut().enumerate() {
                    for d in 0..dim {
                        os[oi * dim + d] = state[d * ll + l];
                    }
                }
                oi += 1;
            }
        }
        let mut out = Vec::with_capacity(ll);
        for (l, ((tape, meter), obs_s)) in tapes
            .into_iter()
            .zip(meters)
            .zip(obs_states)
            .enumerate()
        {
            let mut final_state = vec![0.0; state_size];
            crate::linalg::lane_gather(&state, l, ll, &mut final_state);
            out.push(ForwardOut {
                final_state,
                tape,
                obs_states: obs_s,
                retained: meter.current(),
            });
        }
        ws.put(tmp);
        ws.put(dw);
        ws.put(state);
        ws_pool.put(ws);
        out
    });
    let fwd: Vec<ForwardOut> = fwd_groups.into_iter().flatten().collect();

    // ---- barrier: the batch loss couples samples ------------------------
    let obs_all = gather_obs(&fwd, n_obs, dim);
    let (loss_val, cots) = loss.eval_grad(&obs_all, batch, n_obs, dim);
    let tape_retained: usize = fwd.iter().map(|f| f.retained).sum();

    // ---- backward: lane-blocked sweep, per-lane gradients reduced in
    // fixed batch order --------------------------------------------------
    let fwd_ref = &fwd;
    let cots_ref = &cots;
    let per_group: Vec<Vec<(Vec<f64>, usize)>> = parallel_map(parallelism, groups, |g| {
        let lo = g * lanes;
        let ll = lanes.min(batch - lo);
        let mut ws = ws_pool.take();
        // Lane-contiguous parameter cotangents: lane l accumulates into
        // [l*np, (l+1)*np) in exactly the per-sample order, so the final
        // fixed-batch-order reduction is unchanged by lane grouping.
        let mut d_theta_lanes = vec![0.0; ll * np];
        let mut meters: Vec<MemMeter> = (0..ll).map(|_| MemMeter::new()).collect();
        let mut seg_bufs: Vec<MeteredTape> = (0..ll).map(|_| MeteredTape::new()).collect();
        let mut lambda = ws.take(state_size * ll);
        let mut state = ws.take(state_size * ll);
        for l in 0..ll {
            crate::linalg::lane_scatter(&fwd_ref[lo + l].final_state, l, ll, &mut state);
        }
        let mut dw = ws.take(noise_dim * ll);
        let mut dwm = ws.take(noise_dim * ll);
        let mut prev = ws.take(state_size * ll);
        let mut recon = ws.take(state_size * ll);
        let mut tmp = ws.take(state_size);
        let mut oi = n_obs;
        for n in (0..steps).rev() {
            while oi > 0 && obs[oi - 1] == n + 1 {
                oi -= 1;
                for l in 0..ll {
                    for d in 0..dim {
                        lambda[d * ll + l] += cots_ref[((lo + l) * n_obs + oi) * dim + d];
                    }
                }
            }
            let t = n as f64 * h;
            pack_noise(paths, lo, ll, n, &mut dw);
            match method {
                AdjointMethod::Full => {
                    for l in 0..ll {
                        crate::linalg::lane_scatter(
                            fwd_ref[lo + l].tape.get(n),
                            l,
                            ll,
                            &mut prev,
                        );
                    }
                    stepper.backprop_step_lanes_ws(
                        vf,
                        t,
                        h,
                        &dw,
                        &prev,
                        &mut lambda,
                        &mut d_theta_lanes,
                        ll,
                        &mut ws,
                    );
                }
                AdjointMethod::Reversible => {
                    stepper.step_back_lanes_ws(vf, t, h, &dw, &mut state, ll, &mut ws);
                    stepper.backprop_step_lanes_ws(
                        vf,
                        t,
                        h,
                        &dw,
                        &state,
                        &mut lambda,
                        &mut d_theta_lanes,
                        ll,
                        &mut ws,
                    );
                }
                AdjointMethod::Recursive => {
                    if seg_bufs[0].is_empty() {
                        // Rebuild the whole segment lane-blocked, filling
                        // each lane's (metered) segment buffer with exactly
                        // the states the per-sample sweep would tape.
                        let seg_start = (n / seg) * seg;
                        let ckpt_idx = n / seg;
                        for (l, sb) in seg_bufs.iter_mut().enumerate() {
                            let s = fwd_ref[lo + l].tape.get(ckpt_idx);
                            crate::linalg::lane_scatter(s, l, ll, &mut recon);
                            sb.push(s, &mut meters[l]);
                        }
                        for m in seg_start..n {
                            pack_noise(paths, lo, ll, m, &mut dwm);
                            stepper.step_lanes_ws(
                                vf,
                                m as f64 * h,
                                h,
                                &dwm,
                                &mut recon,
                                ll,
                                &mut ws,
                            );
                            for (l, sb) in seg_bufs.iter_mut().enumerate() {
                                crate::linalg::lane_gather(&recon, l, ll, &mut tmp);
                                sb.push(&tmp, &mut meters[l]);
                            }
                        }
                    }
                    for (l, sb) in seg_bufs.iter_mut().enumerate() {
                        let p = sb.pop(&mut meters[l]).expect("segment buffer underflow");
                        crate::linalg::lane_scatter(&p, l, ll, &mut prev);
                    }
                    stepper.backprop_step_lanes_ws(
                        vf,
                        t,
                        h,
                        &dw,
                        &prev,
                        &mut lambda,
                        &mut d_theta_lanes,
                        ll,
                        &mut ws,
                    );
                }
            }
        }
        ws.put(tmp);
        ws.put(recon);
        ws.put(prev);
        ws.put(dwm);
        ws.put(dw);
        ws.put(state);
        ws.put(lambda);
        ws_pool.put(ws);
        (0..ll)
            .map(|l| {
                (
                    d_theta_lanes[l * np..(l + 1) * np].to_vec(),
                    meters[l].peak_f64s(),
                )
            })
            .collect()
    });
    let per_sample: Vec<(Vec<f64>, usize)> = per_group.into_iter().flatten().collect();

    let (d_theta, peak) = reduce_per_sample(&per_sample, np, base_mem, tape_retained);
    (loss_val, d_theta, peak)
}

/// The per-sample (`lanes = 1`) engine — the pre-lane hot path, kept intact
/// as both the fallback for non-lane-blocked steppers and the bitwise
/// reference the lane path is pinned against.
#[allow(clippy::too_many_arguments)]
fn batch_grad_euclidean_scalar(
    stepper: &dyn Stepper,
    method: AdjointMethod,
    vf: &dyn DiffVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
    parallelism: usize,
    ws_pool: &WorkspacePool,
) -> (f64, Vec<f64>, usize) {
    let batch = y0s.len();
    let dim = vf.dim();
    let n_obs = obs.len();
    let steps = paths[0].steps();
    let h = paths[0].h;
    let state_size = stepper.state_size(dim);
    let seg = (steps as f64).sqrt().ceil() as usize;
    // Shared registers: current state + cotangent, the observation matrix,
    // and the aggregated parameter gradient.
    let base_mem = 2 * state_size + batch * n_obs * dim + vf.num_params();

    // ---- forward: all samples independent -------------------------------
    // Per-worker solver scratch from the caller's pool, shared between the
    // forward and backward fan-outs so the warm buffers survive the loss
    // barrier (and, for a pool owned by a training loop, the epoch
    // boundary).
    let fwd: Vec<ForwardOut> = parallel_map(parallelism, batch, |b| {
        let mut ws = ws_pool.take();
        let mut meter = MemMeter::new();
        let mut tape = MeteredTape::new();
        let mut obs_states = vec![0.0; n_obs * dim];
        let mut state = stepper.init_state(vf, 0.0, &y0s[b]);
        if method != AdjointMethod::Reversible {
            tape.push(&state, &mut meter);
        }
        let mut oi = 0;
        for n in 0..steps {
            let t = n as f64 * h;
            stepper.step_ws(vf, t, h, paths[b].increment(n), &mut state, &mut ws);
            match method {
                AdjointMethod::Full => tape.push(&state, &mut meter),
                AdjointMethod::Recursive => {
                    if (n + 1) % seg == 0 {
                        tape.push(&state, &mut meter);
                    }
                }
                AdjointMethod::Reversible => {}
            }
            while oi < n_obs && obs[oi] == n + 1 {
                obs_states[oi * dim..(oi + 1) * dim].copy_from_slice(&state[..dim]);
                oi += 1;
            }
        }
        ws_pool.put(ws);
        ForwardOut {
            final_state: state,
            tape,
            obs_states,
            retained: meter.current(),
        }
    });

    // ---- barrier: the batch loss couples samples ------------------------
    let obs_all = gather_obs(&fwd, n_obs, dim);
    let (loss_val, cots) = loss.eval_grad(&obs_all, batch, n_obs, dim);
    let tape_retained: usize = fwd.iter().map(|f| f.retained).sum();

    // ---- backward: per-sample gradients, reduced in batch order ---------
    let fwd_ref = &fwd;
    let cots_ref = &cots;
    let per_sample: Vec<(Vec<f64>, usize)> = parallel_map(parallelism, batch, |b| {
        let fw = &fwd_ref[b];
        let mut ws = ws_pool.take();
        let mut d_theta = vec![0.0; vf.num_params()];
        let mut meter = MemMeter::new(); // backward transients only
        let mut lambda = vec![0.0; state_size];
        let mut state = fw.final_state.clone();
        let mut oi = n_obs;
        let mut seg_buf = MeteredTape::new();
        for n in (0..steps).rev() {
            while oi > 0 && obs[oi - 1] == n + 1 {
                oi -= 1;
                for d in 0..dim {
                    lambda[d] += cots_ref[(b * n_obs + oi) * dim + d];
                }
            }
            let t = n as f64 * h;
            let dw = paths[b].increment(n);
            match method {
                AdjointMethod::Full => {
                    stepper.backprop_step_ws(
                        vf,
                        t,
                        h,
                        dw,
                        fw.tape.get(n),
                        &mut lambda,
                        &mut d_theta,
                        &mut ws,
                    );
                }
                AdjointMethod::Reversible => {
                    stepper.step_back_ws(vf, t, h, dw, &mut state, &mut ws);
                    stepper.backprop_step_ws(
                        vf, t, h, dw, &state, &mut lambda, &mut d_theta, &mut ws,
                    );
                }
                AdjointMethod::Recursive => {
                    if seg_buf.is_empty() {
                        let seg_start = (n / seg) * seg;
                        let ckpt_idx = n / seg;
                        let mut s = fw.tape.get(ckpt_idx).to_vec();
                        seg_buf.push(&s, &mut meter);
                        for m in seg_start..n {
                            stepper.step_ws(
                                vf,
                                m as f64 * h,
                                h,
                                paths[b].increment(m),
                                &mut s,
                                &mut ws,
                            );
                            seg_buf.push(&s, &mut meter);
                        }
                    }
                    let prev = seg_buf.pop(&mut meter).expect("segment buffer underflow");
                    stepper.backprop_step_ws(
                        vf, t, h, dw, &prev, &mut lambda, &mut d_theta, &mut ws,
                    );
                }
            }
        }
        ws_pool.put(ws);
        (d_theta, meter.peak_f64s())
    });

    let (d_theta, peak) = reduce_per_sample(&per_sample, vf.num_params(), base_mem, tape_retained);
    (loss_val, d_theta, peak)
}

/// [`batch_grad_euclidean_par`] at the configured default parallelism.
pub fn batch_grad_euclidean(
    stepper: &dyn Stepper,
    method: AdjointMethod,
    vf: &dyn DiffVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
) -> (f64, Vec<f64>, usize) {
    batch_grad_euclidean_par(
        stepper,
        method,
        vf,
        y0s,
        paths,
        obs,
        loss,
        crate::config::default_parallelism(),
    )
}

/// Batch forward+backward on a homogeneous space (Algorithm 2 per sample),
/// fanned out over `parallelism` workers.
/// Returns (loss, d_theta, peak adjoint memory); outputs are
/// bitwise-identical for every `parallelism`.
pub fn batch_grad_manifold_par(
    stepper: &dyn ManifoldStepper,
    method: AdjointMethod,
    sp: &dyn HomogeneousSpace,
    vf: &dyn DiffManifoldVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
    parallelism: usize,
) -> (f64, Vec<f64>, usize) {
    batch_grad_manifold_pool(
        stepper,
        method,
        sp,
        vf,
        y0s,
        paths,
        obs,
        loss,
        parallelism,
        &WorkspacePool::new(),
    )
}

/// [`batch_grad_manifold_par`] drawing per-worker solver scratch from a
/// **caller-owned** [`WorkspacePool`] — the manifold side of
/// [`batch_grad_euclidean_pool`], with the same warm-across-epochs purpose
/// and the same bitwise-invisibility guarantee.
///
/// Workers claim **lane groups** of [`crate::config::default_lanes`]
/// samples (override via [`batch_grad_manifold_pool_lanes`]) and step the
/// whole group per stage in structure-of-arrays layout — generator panels,
/// batched matrix exponentials and the lane-blocked adjoint sweep. Results
/// are bitwise-identical at every lane count.
#[allow(clippy::too_many_arguments)]
pub fn batch_grad_manifold_pool(
    stepper: &dyn ManifoldStepper,
    method: AdjointMethod,
    sp: &dyn HomogeneousSpace,
    vf: &dyn DiffManifoldVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
    parallelism: usize,
    ws_pool: &WorkspacePool,
) -> (f64, Vec<f64>, usize) {
    batch_grad_manifold_pool_lanes(
        stepper,
        method,
        sp,
        vf,
        y0s,
        paths,
        obs,
        loss,
        parallelism,
        ws_pool,
        crate::config::default_lanes(),
    )
}

/// [`batch_grad_manifold_pool`] with an explicit lane-group width.
///
/// `lanes = 1` runs the per-sample engine; `lanes = L > 1` steps groups of
/// `L` samples at once through the manifold stepper's `*_lanes_ws` entry
/// points — forward, reversible `step_back`, and the whole adjoint sweep —
/// so every solver stage evaluates the vector field as one lane-major
/// generator panel and every group exponential runs through the batched
/// [`crate::linalg::expm_lanes_into`] kernels. Per-sample noise streams,
/// per-sample tapes/memory meters, and the fixed-batch-order gradient
/// reduction are all preserved, so loss, gradient and memory figures are
/// **bitwise-identical at every worker AND lane count** (pinned by
/// `rust/tests/determinism.rs`). Stepper/field pairs without lane-blocked
/// implementations fall back to `lanes = 1`.
#[allow(clippy::too_many_arguments)]
pub fn batch_grad_manifold_pool_lanes(
    stepper: &dyn ManifoldStepper,
    method: AdjointMethod,
    sp: &dyn HomogeneousSpace,
    vf: &dyn DiffManifoldVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
    parallelism: usize,
    ws_pool: &WorkspacePool,
    lanes: usize,
) -> (f64, Vec<f64>, usize) {
    let lanes = effective_lanes_manifold(stepper, vf, lanes);
    if lanes <= 1 {
        return batch_grad_manifold_scalar(
            stepper, method, sp, vf, y0s, paths, obs, loss, parallelism, ws_pool,
        );
    }
    let batch = y0s.len();
    let dim = sp.point_dim();
    let noise_dim = vf.noise_dim();
    let np = vf.num_params();
    let n_obs = obs.len();
    let steps = paths[0].steps();
    let h = paths[0].h;
    let seg = (steps as f64).sqrt().ceil() as usize;
    let base_mem = 2 * dim + 2 * sp.algebra_dim() + batch * n_obs * dim + np;
    let groups = (batch + lanes - 1) / lanes;

    // ---- forward: lane groups independent -------------------------------
    let fwd_groups: Vec<Vec<ForwardOut>> = parallel_map(parallelism, groups, |g| {
        let lo = g * lanes;
        let ll = lanes.min(batch - lo);
        let mut ws = ws_pool.take();
        let mut meters: Vec<MemMeter> = (0..ll).map(|_| MemMeter::new()).collect();
        let mut tapes: Vec<MeteredTape> = (0..ll).map(|_| MeteredTape::new()).collect();
        let mut obs_states: Vec<Vec<f64>> = (0..ll).map(|_| vec![0.0; n_obs * dim]).collect();
        let mut y = ws.take(dim * ll);
        for l in 0..ll {
            crate::linalg::lane_scatter(&y0s[lo + l], l, ll, &mut y);
            if method != AdjointMethod::Reversible {
                tapes[l].push(&y0s[lo + l], &mut meters[l]);
            }
        }
        let mut dw = ws.take(noise_dim * ll);
        let mut tmp = ws.take(dim);
        let mut oi = 0;
        for n in 0..steps {
            let t = n as f64 * h;
            pack_noise(paths, lo, ll, n, &mut dw);
            stepper.step_lanes_ws(sp, vf, t, h, &dw, &mut y, ll, &mut ws);
            let record = match method {
                AdjointMethod::Full => true,
                AdjointMethod::Recursive => (n + 1) % seg == 0,
                AdjointMethod::Reversible => false,
            };
            if record {
                for l in 0..ll {
                    crate::linalg::lane_gather(&y, l, ll, &mut tmp);
                    tapes[l].push(&tmp, &mut meters[l]);
                }
            }
            while oi < n_obs && obs[oi] == n + 1 {
                for (l, os) in obs_states.iter_mut().enumerate() {
                    for d in 0..dim {
                        os[oi * dim + d] = y[d * ll + l];
                    }
                }
                oi += 1;
            }
        }
        let mut out = Vec::with_capacity(ll);
        for (l, ((tape, meter), obs_s)) in tapes
            .into_iter()
            .zip(meters)
            .zip(obs_states)
            .enumerate()
        {
            let mut final_state = vec![0.0; dim];
            crate::linalg::lane_gather(&y, l, ll, &mut final_state);
            out.push(ForwardOut {
                final_state,
                tape,
                obs_states: obs_s,
                retained: meter.current(),
            });
        }
        ws.put(tmp);
        ws.put(dw);
        ws.put(y);
        ws_pool.put(ws);
        out
    });
    let fwd: Vec<ForwardOut> = fwd_groups.into_iter().flatten().collect();

    // ---- barrier: the batch loss couples samples ------------------------
    let obs_all = gather_obs(&fwd, n_obs, dim);
    let (loss_val, cots) = loss.eval_grad(&obs_all, batch, n_obs, dim);
    let tape_retained: usize = fwd.iter().map(|f| f.retained).sum();

    // ---- backward: lane-blocked sweep, per-lane gradients reduced in
    // fixed batch order --------------------------------------------------
    let fwd_ref = &fwd;
    let cots_ref = &cots;
    let per_group: Vec<Vec<(Vec<f64>, usize)>> = parallel_map(parallelism, groups, |g| {
        let lo = g * lanes;
        let ll = lanes.min(batch - lo);
        let mut ws = ws_pool.take();
        // Lane-contiguous parameter cotangents: lane l accumulates into
        // [l*np, (l+1)*np) in exactly the per-sample order, so the final
        // fixed-batch-order reduction is unchanged by lane grouping.
        let mut d_theta_lanes = vec![0.0; ll * np];
        let mut meters: Vec<MemMeter> = (0..ll).map(|_| MemMeter::new()).collect();
        let mut seg_bufs: Vec<MeteredTape> = (0..ll).map(|_| MeteredTape::new()).collect();
        let mut lambda = ws.take(dim * ll);
        let mut y = ws.take(dim * ll);
        for l in 0..ll {
            crate::linalg::lane_scatter(&fwd_ref[lo + l].final_state, l, ll, &mut y);
        }
        let mut dw = ws.take(noise_dim * ll);
        let mut dwm = ws.take(noise_dim * ll);
        let mut prev = ws.take(dim * ll);
        let mut recon = ws.take(dim * ll);
        let mut tmp = ws.take(dim);
        let mut oi = n_obs;
        for n in (0..steps).rev() {
            while oi > 0 && obs[oi - 1] == n + 1 {
                oi -= 1;
                for l in 0..ll {
                    for d in 0..dim {
                        lambda[d * ll + l] += cots_ref[((lo + l) * n_obs + oi) * dim + d];
                    }
                }
            }
            let t = n as f64 * h;
            pack_noise(paths, lo, ll, n, &mut dw);
            match method {
                AdjointMethod::Full => {
                    for l in 0..ll {
                        crate::linalg::lane_scatter(
                            fwd_ref[lo + l].tape.get(n),
                            l,
                            ll,
                            &mut prev,
                        );
                    }
                    stepper.backprop_step_lanes_ws(
                        sp,
                        vf,
                        t,
                        h,
                        &dw,
                        &prev,
                        &mut lambda,
                        &mut d_theta_lanes,
                        ll,
                        &mut ws,
                    );
                }
                AdjointMethod::Reversible => {
                    stepper.step_back_lanes_ws(sp, vf, t, h, &dw, &mut y, ll, &mut ws);
                    stepper.backprop_step_lanes_ws(
                        sp,
                        vf,
                        t,
                        h,
                        &dw,
                        &y,
                        &mut lambda,
                        &mut d_theta_lanes,
                        ll,
                        &mut ws,
                    );
                }
                AdjointMethod::Recursive => {
                    if seg_bufs[0].is_empty() {
                        // Rebuild the whole segment lane-blocked, filling
                        // each lane's (metered) segment buffer with exactly
                        // the states the per-sample sweep would tape.
                        let seg_start = (n / seg) * seg;
                        let ckpt_idx = n / seg;
                        for (l, sb) in seg_bufs.iter_mut().enumerate() {
                            let s = fwd_ref[lo + l].tape.get(ckpt_idx);
                            crate::linalg::lane_scatter(s, l, ll, &mut recon);
                            sb.push(s, &mut meters[l]);
                        }
                        for m in seg_start..n {
                            pack_noise(paths, lo, ll, m, &mut dwm);
                            stepper.step_lanes_ws(
                                sp,
                                vf,
                                m as f64 * h,
                                h,
                                &dwm,
                                &mut recon,
                                ll,
                                &mut ws,
                            );
                            for (l, sb) in seg_bufs.iter_mut().enumerate() {
                                crate::linalg::lane_gather(&recon, l, ll, &mut tmp);
                                sb.push(&tmp, &mut meters[l]);
                            }
                        }
                    }
                    for (l, sb) in seg_bufs.iter_mut().enumerate() {
                        let p = sb.pop(&mut meters[l]).expect("segment buffer underflow");
                        crate::linalg::lane_scatter(&p, l, ll, &mut prev);
                    }
                    stepper.backprop_step_lanes_ws(
                        sp,
                        vf,
                        t,
                        h,
                        &dw,
                        &prev,
                        &mut lambda,
                        &mut d_theta_lanes,
                        ll,
                        &mut ws,
                    );
                }
            }
        }
        ws.put(tmp);
        ws.put(recon);
        ws.put(prev);
        ws.put(dwm);
        ws.put(dw);
        ws.put(y);
        ws.put(lambda);
        ws_pool.put(ws);
        (0..ll)
            .map(|l| {
                (
                    d_theta_lanes[l * np..(l + 1) * np].to_vec(),
                    meters[l].peak_f64s(),
                )
            })
            .collect()
    });
    let per_sample: Vec<(Vec<f64>, usize)> = per_group.into_iter().flatten().collect();

    let (d_theta, peak) = reduce_per_sample(&per_sample, np, base_mem, tape_retained);
    (loss_val, d_theta, peak)
}

/// The per-sample (`lanes = 1`) manifold engine — the pre-lane hot path,
/// kept intact as both the fallback for non-lane-blocked stepper/field
/// pairs and the bitwise reference the lane path is pinned against.
#[allow(clippy::too_many_arguments)]
fn batch_grad_manifold_scalar(
    stepper: &dyn ManifoldStepper,
    method: AdjointMethod,
    sp: &dyn HomogeneousSpace,
    vf: &dyn DiffManifoldVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
    parallelism: usize,
    ws_pool: &WorkspacePool,
) -> (f64, Vec<f64>, usize) {
    let batch = y0s.len();
    let dim = sp.point_dim();
    let n_obs = obs.len();
    let steps = paths[0].steps();
    let h = paths[0].h;
    let seg = (steps as f64).sqrt().ceil() as usize;
    let base_mem = 2 * dim + 2 * sp.algebra_dim() + batch * n_obs * dim + vf.num_params();

    let fwd: Vec<ForwardOut> = parallel_map(parallelism, batch, |b| {
        let mut ws = ws_pool.take();
        let mut meter = MemMeter::new();
        let mut tape = MeteredTape::new();
        let mut obs_states = vec![0.0; n_obs * dim];
        let mut y = y0s[b].clone();
        if method != AdjointMethod::Reversible {
            tape.push(&y, &mut meter);
        }
        let mut oi = 0;
        for n in 0..steps {
            stepper.step_ws(sp, vf, n as f64 * h, h, paths[b].increment(n), &mut y, &mut ws);
            match method {
                AdjointMethod::Full => tape.push(&y, &mut meter),
                AdjointMethod::Recursive => {
                    if (n + 1) % seg == 0 {
                        tape.push(&y, &mut meter);
                    }
                }
                AdjointMethod::Reversible => {}
            }
            while oi < n_obs && obs[oi] == n + 1 {
                obs_states[oi * dim..(oi + 1) * dim].copy_from_slice(&y);
                oi += 1;
            }
        }
        ws_pool.put(ws);
        ForwardOut {
            final_state: y,
            tape,
            obs_states,
            retained: meter.current(),
        }
    });

    let obs_all = gather_obs(&fwd, n_obs, dim);
    let (loss_val, cots) = loss.eval_grad(&obs_all, batch, n_obs, dim);
    let tape_retained: usize = fwd.iter().map(|f| f.retained).sum();

    let fwd_ref = &fwd;
    let cots_ref = &cots;
    let per_sample: Vec<(Vec<f64>, usize)> = parallel_map(parallelism, batch, |b| {
        let fw = &fwd_ref[b];
        let mut ws = ws_pool.take();
        let mut d_theta = vec![0.0; vf.num_params()];
        let mut meter = MemMeter::new();
        let mut lambda = vec![0.0; dim];
        let mut y = fw.final_state.clone();
        let mut oi = n_obs;
        let mut seg_buf = MeteredTape::new();
        for n in (0..steps).rev() {
            while oi > 0 && obs[oi - 1] == n + 1 {
                oi -= 1;
                for d in 0..dim {
                    lambda[d] += cots_ref[(b * n_obs + oi) * dim + d];
                }
            }
            let t = n as f64 * h;
            let dw = paths[b].increment(n);
            match method {
                AdjointMethod::Full => {
                    stepper.backprop_step_ws(
                        sp,
                        vf,
                        t,
                        h,
                        dw,
                        fw.tape.get(n),
                        &mut lambda,
                        &mut d_theta,
                        &mut ws,
                    );
                }
                AdjointMethod::Reversible => {
                    stepper.step_back_ws(sp, vf, t, h, dw, &mut y, &mut ws);
                    stepper.backprop_step_ws(
                        sp, vf, t, h, dw, &y, &mut lambda, &mut d_theta, &mut ws,
                    );
                }
                AdjointMethod::Recursive => {
                    if seg_buf.is_empty() {
                        let seg_start = (n / seg) * seg;
                        let ckpt_idx = n / seg;
                        let mut s = fw.tape.get(ckpt_idx).to_vec();
                        seg_buf.push(&s, &mut meter);
                        for m in seg_start..n {
                            stepper.step_ws(
                                sp,
                                vf,
                                m as f64 * h,
                                h,
                                paths[b].increment(m),
                                &mut s,
                                &mut ws,
                            );
                            seg_buf.push(&s, &mut meter);
                        }
                    }
                    let prev = seg_buf.pop(&mut meter).expect("segment buffer underflow");
                    stepper.backprop_step_ws(
                        sp, vf, t, h, dw, &prev, &mut lambda, &mut d_theta, &mut ws,
                    );
                }
            }
        }
        ws_pool.put(ws);
        (d_theta, meter.peak_f64s())
    });

    let (d_theta, peak) = reduce_per_sample(&per_sample, vf.num_params(), base_mem, tape_retained);
    (loss_val, d_theta, peak)
}

/// [`batch_grad_manifold_par`] at the configured default parallelism.
pub fn batch_grad_manifold(
    stepper: &dyn ManifoldStepper,
    method: AdjointMethod,
    sp: &dyn HomogeneousSpace,
    vf: &dyn DiffManifoldVectorField,
    y0s: &[Vec<f64>],
    paths: &[BrownianPath],
    obs: &[usize],
    loss: &dyn BatchLoss,
) -> (f64, Vec<f64>, usize) {
    batch_grad_manifold_par(
        stepper,
        method,
        sp,
        vf,
        y0s,
        paths,
        obs,
        loss,
        crate::config::default_parallelism(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::losses::MomentMatch;
    use crate::models::ou::OuParams;
    use crate::nn::neural_sde::NeuralSde;
    use crate::solvers::LowStorageStepper;

    /// End-to-end OU training smoke, now driven through
    /// [`crate::train::Trainer`] directly (migrated from the removed
    /// `train_euclidean` shim, whose deprecation grace period has
    /// elapsed): the reversible adjoint reduces the loss, and running the
    /// engine on **caller-owned optimiser state** via `run_resumed` is
    /// bitwise-identical to the fresh-optimiser `run` path — optimiser
    /// handoff is a resume mechanism, not a second training path.
    #[test]
    fn training_reduces_loss_on_ou() {
        use crate::nn::optim::Optimizer;
        use crate::train::{EuclideanProblem, FlatParams, OptimSpec, TrainConfig, Trainer};

        let mut rng = Pcg64::new(20);
        let ou = OuParams::default();
        let steps = 16;
        let h = 2.0 / steps as f64;
        let obs: Vec<usize> = (4..=steps).step_by(4).collect();
        // Exact-moment targets at the observation times.
        let (mean_all, m2_all) = ou.moment_targets(0.0, steps, h, 4000, &mut rng);
        let loss = MomentMatch {
            target_mean: obs.iter().map(|&i| mean_all[i]).collect(),
            target_m2: obs.iter().map(|&i| m2_all[i]).collect(),
        };
        let st = LowStorageStepper::ees25();
        let batch = 64;
        let make_sampler = || {
            move |rng: &mut Pcg64| {
                let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.0]).collect();
                let paths: Vec<BrownianPath> = (0..batch)
                    .map(|_| BrownianPath::sample(rng, 1, steps, h))
                    .collect();
                (y0s, paths)
            }
        };

        // Caller-owned optimiser state through run_resumed (the legacy
        // wrapper's contract, driven on the engine directly).
        let model = NeuralSde::lsde(1, 8, 1, true, &mut rng);
        let mut opt = Optimizer::adam(0.02, model.num_params());
        let mut problem = EuclideanProblem::new(
            model,
            &st,
            AdjointMethod::Reversible,
            make_sampler(),
            obs.clone(),
            &loss,
        );
        let trainer = Trainer::new(
            TrainConfig::new(40).group(OptimSpec::of(&opt), Some(1.0)),
        );
        let mut opts = vec![opt.clone()];
        let log = trainer.run_resumed(&mut problem, &mut rng, &mut [], &mut opts);
        opt = opts.remove(0);
        let first: f64 = log.history[..5].iter().map(|m| m.loss).sum::<f64>() / 5.0;
        let last: f64 = log.history[35..].iter().map(|m| m.loss).sum::<f64>() / 5.0;
        assert!(
            last < 0.7 * first,
            "loss must decrease: {first} -> {last}"
        );
        // The handed-back optimiser advanced through all 40 steps.
        match &opt {
            Optimizer::Adam { t, .. } => assert_eq!(*t, 40),
            other => panic!("expected Adam state, got {other:?}"),
        }

        // The identical run through the fresh-optimiser `run` entry point
        // must be bitwise-identical.
        let mut rng2 = Pcg64::new(20);
        let (mean_all2, m2_all2) = ou.moment_targets(0.0, steps, h, 4000, &mut rng2);
        let loss2 = MomentMatch {
            target_mean: obs.iter().map(|&i| mean_all2[i]).collect(),
            target_m2: obs.iter().map(|&i| m2_all2[i]).collect(),
        };
        let model2 = NeuralSde::lsde(1, 8, 1, true, &mut rng2);
        let mut problem2 = EuclideanProblem::new(
            model2,
            &st,
            AdjointMethod::Reversible,
            make_sampler(),
            obs.clone(),
            &loss2,
        );
        let trainer2 = Trainer::new(
            TrainConfig::new(40).group(OptimSpec::Adam { lr: 0.02 }, Some(1.0)),
        );
        let log2 = trainer2.run(&mut problem2, &mut rng2);
        assert_eq!(log.history.len(), log2.history.len());
        for (a, b) in log.history.iter().zip(log2.history.iter()) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "epoch {}", a.epoch);
        }
        for (a, b) in FlatParams::params(&problem.model)
            .iter()
            .zip(FlatParams::params(&problem2.model).iter())
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// Batch gradients agree across adjoints (Table-12 property at batch level).
    #[test]
    fn batch_adjoints_agree() {
        let mut rng = Pcg64::new(21);
        let model = NeuralSde::lsde(2, 6, 1, false, &mut rng);
        let st = LowStorageStepper::ees25();
        let steps = 20;
        let h = 0.05;
        let batch = 4;
        let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.1, -0.1]).collect();
        let paths: Vec<BrownianPath> = (0..batch)
            .map(|_| BrownianPath::sample(&mut rng, 2, steps, h))
            .collect();
        let obs = vec![10, 20];
        let mut data = vec![0.0; batch * 2 * 2];
        rng.fill_normal(&mut data);
        let loss = MomentMatch::from_data(&data, batch, 2, 2);
        let (l0, g0, m_full) = batch_grad_euclidean(
            &st,
            AdjointMethod::Full,
            &model,
            &y0s,
            &paths,
            &obs,
            &loss,
        );
        for method in [AdjointMethod::Recursive, AdjointMethod::Reversible] {
            let (l, g, m) =
                batch_grad_euclidean(&st, method, &model, &y0s, &paths, &obs, &loss);
            assert!((l - l0).abs() < 1e-10);
            for (a, b) in g.iter().zip(g0.iter()) {
                assert!((a - b).abs() < 1e-7 * (1.0 + b.abs()), "{a} vs {b}");
            }
            assert!(m < m_full, "{} must use less memory", method.name());
        }
    }

    /// The engine's central contract: every worker count yields bit-equal
    /// losses, gradients and memory figures.
    #[test]
    fn parallelism_is_bitwise_invisible() {
        let mut rng = Pcg64::new(33);
        let model = NeuralSde::lsde(3, 8, 1, false, &mut rng);
        let st = LowStorageStepper::ees25();
        let (steps, h, batch) = (12, 0.05, 7);
        let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.2, 0.0, -0.1]).collect();
        let paths = sample_paths_par(&mut rng, batch, 3, steps, h, 3);
        let obs = vec![6, 12];
        let mut data = vec![0.0; batch * 2 * 3];
        rng.fill_normal(&mut data);
        let loss = MomentMatch::from_data(&data, batch, 2, 3);
        for method in [
            AdjointMethod::Full,
            AdjointMethod::Recursive,
            AdjointMethod::Reversible,
        ] {
            let (l1, g1, m1) = batch_grad_euclidean_par(
                &st, method, &model, &y0s, &paths, &obs, &loss, 1,
            );
            for p in [2, 4, 16] {
                let (lp, gp, mp) = batch_grad_euclidean_par(
                    &st, method, &model, &y0s, &paths, &obs, &loss, p,
                );
                assert_eq!(l1.to_bits(), lp.to_bits(), "{} p={p}", method.name());
                assert_eq!(m1, mp, "{} p={p}", method.name());
                for (a, b) in g1.iter().zip(gp.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{} p={p}", method.name());
                }
            }
        }
    }

    /// Adaptive batch solves over per-sample virtual Brownian trees are
    /// bitwise worker-count-invariant, including the accept/reject
    /// histories.
    #[test]
    fn adaptive_batch_bitwise_invariant_in_parallelism() {
        let mut rng = Pcg64::new(55);
        let model = NeuralSde::lsde(2, 6, 2, false, &mut rng);
        let batch = 6;
        let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.2, -0.1]).collect();
        let trees = {
            let mut root = Pcg64::new(77);
            sample_trees(&mut root, batch, 2, 0.0, 1.0, 16)
        };
        let ctrl = AdaptiveController::default();
        let base = batch_integrate_adaptive_par(&model, &y0s, &trees, 0.1, &ctrl, 1);
        for p in [2, 4, 8] {
            let run = batch_integrate_adaptive_par(&model, &y0s, &trees, 0.1, &ctrl, p);
            for (a, b) in base.iter().zip(run.iter()) {
                assert_eq!(a.steps_accepted, b.steps_accepted, "P={p}");
                assert_eq!(a.steps_rejected, b.steps_rejected, "P={p}");
                for (x, y) in a.y.iter().zip(b.y.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "P={p}");
                }
            }
        }
        // Distinct samples see distinct noise: terminal states differ.
        assert_ne!(base[0].y, base[1].y);
    }

    /// Split-stream path sampling is parallelism-invariant and per-sample
    /// independent.
    #[test]
    fn sample_paths_split_streams_deterministic() {
        let paths_at = |p: usize| {
            let mut rng = Pcg64::new(77);
            sample_paths_par(&mut rng, 5, 2, 8, 0.1, p)
        };
        let a = paths_at(1);
        let b = paths_at(4);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.dw, y.dw);
        }
        // Distinct samples see distinct noise.
        assert_ne!(a[0].dw, a[1].dw);
    }
}
