//! The million-path streaming risk engine behind `ees risk` (ROADMAP open
//! item 4): sweep 10⁵–10⁷ Monte Carlo paths of a scenario through the
//! solver stack and fold every payoff into the O(1)-memory streaming
//! estimators of [`crate::stats`] — mean/variance (Welford), quantiles
//! (P²) and tail CVaR — so resident memory is O(chunk × workers),
//! independent of the total path count.
//!
//! # Scenarios
//!
//! - `rbergomi` — fBm-driven rough Bergomi terminal log-price, through the
//!   fractional kernel machinery of [`crate::rng::fbm`] (whose
//!   `riemann_liouville` hot loop is FFT-accelerated for exactly this
//!   sweep) and [`crate::models::stochvol`].
//! - `gbm_portfolio` — a correlated geometric-Brownian book
//!   ([`GbmPortfolio`]); payoff is the equal-weight terminal portfolio
//!   value. Two stepper arms: the lane-blocked EES(2,5) engine
//!   ([`crate::coordinator::batch_terminal_lanes_par`]) and the
//!   diagonal-noise [`Milstein`] baseline, driven by the *same* per-path
//!   noise so their estimates are directly comparable.
//! - `kuramoto` — the paper's stochastic Kuramoto network, integrated in
//!   streaming form (no trajectory, O(N) state) with CF-EES(2,5) on T𝕋ᴺ;
//!   payoff is the terminal order parameter. The mean-field coupling is
//!   evaluated through the order-parameter trick, so a step is O(N) — the
//!   cost profile of a sparse-coupled network — and N ≈ 10⁴ oscillators
//!   are practical.
//!
//! # Determinism & checkpointing
//!
//! Path `i`'s noise comes from the **pure stream function**
//! [`path_stream`]`(seed, i)` — a fresh root generator split at the global
//! path index — so a path's driver depends only on `(seed, i)`, never on
//! which worker, lane, or chunk computed it. Payoffs are produced by
//! index-ordered [`parallel_map`] fan-outs and folded into the estimators
//! on the calling thread in global index order. Estimates are therefore
//! **bitwise-identical across worker counts, lane widths and chunk sizes**,
//! and a sweep checkpointed mid-stream (PR 4 [`Snapshot`] text form, bit
//! exact) resumes to the same final state as an uninterrupted run.

use crate::bench::Table;
use crate::config::Config;
use crate::coordinator::{batch_terminal_lanes_par, parallel_map};
use crate::fault::FaultPlan;
use crate::lie::TTorus;
use crate::memory::WorkspacePool;
use crate::models::gbm::GbmPortfolio;
use crate::models::kuramoto::KuramotoParams;
use crate::models::stochvol::{simulate_price_path, VolModel};
use crate::rng::{BrownianPath, Pcg64};
use crate::solvers::{CfEes, LowStorageStepper, ManifoldStepper, Milstein};
use crate::stats::{Cvar, P2Quantile, Welford};
use crate::train::Snapshot;

/// Scenario names accepted by `[risk] scenario` (and `ees risk --scenario`).
pub const NAMES: [&str; 3] = ["rbergomi", "gbm_portfolio", "kuramoto"];

/// Quantile levels every sweep tracks (besides the CVaR tail).
pub const QUANTILES: [f64; 3] = [0.05, 0.5, 0.95];

/// The registered risk scenarios.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RiskScenario {
    /// Rough Bergomi terminal log-price (fBm-driven, Table 11 parameters).
    RoughBergomi,
    /// Correlated GBM portfolio terminal value ([`GbmPortfolio::paper`]).
    GbmPortfolio,
    /// Stochastic Kuramoto terminal order parameter on T𝕋ᴺ.
    Kuramoto,
}

impl RiskScenario {
    pub fn parse(name: &str) -> crate::Result<Self> {
        Ok(match name {
            "rbergomi" => RiskScenario::RoughBergomi,
            "gbm_portfolio" => RiskScenario::GbmPortfolio,
            "kuramoto" => RiskScenario::Kuramoto,
            other => {
                return Err(crate::format_err!(
                    "unknown risk scenario '{other}' (registered: {})",
                    NAMES.join(", ")
                ))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RiskScenario::RoughBergomi => "rbergomi",
            RiskScenario::GbmPortfolio => "gbm_portfolio",
            RiskScenario::Kuramoto => "kuramoto",
        }
    }

    fn id(&self) -> f64 {
        match self {
            RiskScenario::RoughBergomi => 0.0,
            RiskScenario::GbmPortfolio => 1.0,
            RiskScenario::Kuramoto => 2.0,
        }
    }
}

/// Which integrator arm drives the sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RiskStepper {
    /// The EES family (lane-blocked 2N-EES(2,5) for Euclidean scenarios,
    /// CF-EES(2,5) for the manifold one) — the default.
    Ees,
    /// Diagonal-noise Milstein, the strong-order-1.0 accuracy baseline.
    /// Valid only for scenarios with componentwise diffusion
    /// (`gbm_portfolio`).
    Milstein,
}

impl RiskStepper {
    pub fn parse(name: &str) -> crate::Result<Self> {
        Ok(match name {
            "ees" => RiskStepper::Ees,
            "milstein" => RiskStepper::Milstein,
            other => {
                return Err(crate::format_err!(
                    "unknown risk stepper '{other}' (expected ees | milstein)"
                ))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RiskStepper::Ees => "ees",
            RiskStepper::Milstein => "milstein",
        }
    }

    fn id(&self) -> f64 {
        match self {
            RiskStepper::Ees => 0.0,
            RiskStepper::Milstein => 1.0,
        }
    }
}

/// The pure per-path noise stream: a fresh root generator seeded with
/// `seed`, split at the global path `index`. Because the root is rebuilt
/// for every call, the returned stream is a function of `(seed, index)`
/// alone — the property every invariance guarantee (workers, lanes, chunk
/// size, checkpoint/resume position) rests on.
pub fn path_stream(seed: u64, index: u64) -> Pcg64 {
    Pcg64::new(seed).split(index)
}

/// A parsed `[risk]` configuration.
///
/// `parallelism`, `lanes`, `chunk`, `checkpoint_every` and `fault` are
/// pure execution knobs: estimates are bitwise-identical at every value
/// (they are therefore excluded from the checkpoint fingerprint — a
/// checkpoint taken under fault injection resumes cleanly without it).
/// Everything else changes the sampled distribution and is fingerprinted.
#[derive(Clone, Debug)]
pub struct RiskConfig {
    pub scenario: RiskScenario,
    pub stepper: RiskStepper,
    /// Total Monte Carlo paths in the sweep.
    pub paths: usize,
    /// Solver steps per path (the rough-Bergomi fine grid).
    pub steps: usize,
    /// Physical horizon T.
    pub horizon: f64,
    /// Scenario dimension: portfolio assets / Kuramoto oscillators
    /// (ignored by `rbergomi`, which is scalar).
    pub dim: usize,
    pub seed: u64,
    /// CVaR tail level in (0, 1).
    pub alpha: f64,
    /// Paths processed per fan-out — the resident-memory knob.
    pub chunk: usize,
    pub parallelism: usize,
    pub lanes: usize,
    /// Auto-checkpoint cadence in paths for [`RiskSweep::run_checkpointed`]
    /// (`--checkpoint-every`); 0 disables auto-checkpointing.
    pub checkpoint_every: usize,
    /// Deterministic fault-injection schedule (`[fault]` config /
    /// `EES_FAULT_*` env) — inert unless explicitly armed.
    pub fault: FaultPlan,
}

impl RiskConfig {
    /// Read the `[risk]` section (plus the shared `[exec]` knobs).
    pub fn from_config(cfg: &Config) -> crate::Result<Self> {
        let scenario = RiskScenario::parse(cfg.str_or("risk.scenario", "rbergomi"))?;
        let stepper = RiskStepper::parse(cfg.str_or("risk.stepper", "ees"))?;
        if stepper == RiskStepper::Milstein && scenario != RiskScenario::GbmPortfolio {
            return Err(crate::format_err!(
                "the milstein arm needs componentwise diffusion — only the \
                 gbm_portfolio scenario qualifies (got '{}')",
                scenario.name()
            ));
        }
        let paths = cfg.usize_or("risk.paths", 10_000);
        if paths == 0 {
            return Err(crate::format_err!("[risk] paths must be >= 1"));
        }
        let steps = cfg.usize_or("risk.steps", 64).max(1);
        let horizon = cfg.f64_or("risk.horizon", 1.0);
        let horizon_ok = horizon.is_finite() && horizon > 0.0;
        if !horizon_ok {
            return Err(crate::format_err!("[risk] horizon must be > 0"));
        }
        let default_dim = match scenario {
            RiskScenario::RoughBergomi => 1,
            RiskScenario::GbmPortfolio => 8,
            RiskScenario::Kuramoto => 100,
        };
        let dim = cfg.usize_or("risk.dim", default_dim).max(1);
        let alpha = cfg.f64_or("risk.alpha", 0.95);
        let alpha_ok = alpha > 0.0 && alpha < 1.0;
        if !alpha_ok {
            return Err(crate::format_err!("[risk] alpha must lie in (0, 1)"));
        }
        Ok(Self {
            scenario,
            stepper,
            paths,
            steps,
            horizon,
            dim,
            seed: cfg.usize_or("risk.seed", 42) as u64,
            alpha,
            chunk: cfg.usize_or("risk.chunk", 4096).max(1),
            parallelism: cfg.parallelism().max(1),
            lanes: cfg.lanes(),
            checkpoint_every: cfg.usize_or("risk.checkpoint_every", 0),
            fault: FaultPlan::from_config(cfg)?,
        })
    }

    /// Snapshot format version, stored as fingerprint word 0.
    ///
    /// The estimator `STATE_LEN`s are silently part of the checkpoint
    /// layout (the words after the fingerprint are raw estimator state),
    /// so any change to estimator layout, word order, or fingerprint
    /// contents MUST bump this: a resume across versions then refuses
    /// loudly with a version message instead of misinterpreting words.
    /// History: v1 = the (implicit, unversioned) PR 8 format with an
    /// 8-word fingerprint; v2 prepends this version word (9-word
    /// fingerprint — v1 snapshots are already refused by the length
    /// check).
    pub const SNAPSHOT_VERSION: f64 = 2.0;

    /// The distribution-defining knobs as `f64` words, stored at the head
    /// of every checkpoint so a resume against a different configuration
    /// fails loudly instead of silently mixing estimators. The seed is
    /// stored via its bit pattern (`f64::from_bits`) — comparisons are
    /// bitwise, so a NaN pattern is harmless.
    fn fingerprint(&self) -> Vec<f64> {
        vec![
            Self::SNAPSHOT_VERSION,
            self.scenario.id(),
            self.stepper.id(),
            self.paths as f64,
            self.steps as f64,
            self.horizon,
            self.dim as f64,
            f64::from_bits(self.seed),
            self.alpha,
        ]
    }

    /// `f64` words in [`Self::fingerprint`] (version word included).
    const FP_LEN: usize = 9;
}

/// The estimator bundle one sweep folds payoffs into: Welford moments,
/// a P² quantile per [`QUANTILES`] level, tail CVaR of the **loss**
/// (−payoff, so the tail is the bad outcomes), and running extremes.
#[derive(Clone, Debug)]
pub struct RiskEstimators {
    pub payoff: Welford,
    pub quantiles: Vec<P2Quantile>,
    pub cvar_loss: Cvar,
    pub min: f64,
    pub max: f64,
}

impl RiskEstimators {
    pub fn new(alpha: f64) -> Self {
        Self {
            payoff: Welford::new(),
            quantiles: QUANTILES.iter().map(|&p| P2Quantile::new(p)).collect(),
            cvar_loss: Cvar::new(alpha),
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.payoff.push(x);
        for q in &mut self.quantiles {
            q.push(x);
        }
        self.cvar_loss.push(-x);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// `f64` words in [`Self::state`].
    pub const STATE_LEN: usize =
        Welford::STATE_LEN + QUANTILES.len() * P2Quantile::STATE_LEN + Cvar::STATE_LEN + 2;

    /// Exact bundle state (checkpoint payload).
    pub fn state(&self) -> Vec<f64> {
        let mut s = Vec::with_capacity(Self::STATE_LEN);
        s.extend_from_slice(&self.payoff.state());
        for q in &self.quantiles {
            s.extend(q.state());
        }
        s.extend(self.cvar_loss.state());
        s.push(self.min);
        s.push(self.max);
        s
    }

    pub fn from_state(s: &[f64]) -> crate::Result<Self> {
        if s.len() != Self::STATE_LEN {
            return Err(crate::format_err!(
                "RiskEstimators state needs {} words, got {}",
                Self::STATE_LEN,
                s.len()
            ));
        }
        let mut at = 0;
        let payoff = Welford::from_state(&s[at..at + Welford::STATE_LEN])?;
        at += Welford::STATE_LEN;
        let mut quantiles = Vec::with_capacity(QUANTILES.len());
        for _ in 0..QUANTILES.len() {
            quantiles.push(P2Quantile::from_state(&s[at..at + P2Quantile::STATE_LEN])?);
            at += P2Quantile::STATE_LEN;
        }
        let cvar_loss = Cvar::from_state(&s[at..at + Cvar::STATE_LEN])?;
        at += Cvar::STATE_LEN;
        Ok(Self {
            payoff,
            quantiles,
            cvar_loss,
            min: s[at],
            max: s[at + 1],
        })
    }
}

/// One streaming sweep: configuration + estimator bundle + progress.
#[derive(Clone, Debug)]
pub struct RiskSweep {
    cfg: RiskConfig,
    est: RiskEstimators,
    /// Paths folded so far — the next path to run is exactly `done`.
    done: usize,
}

impl RiskSweep {
    pub fn new(cfg: RiskConfig) -> Self {
        let est = RiskEstimators::new(cfg.alpha);
        Self { cfg, est, done: 0 }
    }

    pub fn cfg(&self) -> &RiskConfig {
        &self.cfg
    }

    pub fn estimators(&self) -> &RiskEstimators {
        &self.est
    }

    pub fn done(&self) -> usize {
        self.done
    }

    /// Serialize the sweep mid-stream: progress in `epoch`, the running
    /// mean in `loss` (informational), configuration fingerprint +
    /// estimator words in `params`. Uses the PR 4 [`Snapshot`] hex-text
    /// form, so the round-trip is bitwise-exact.
    pub fn snapshot(&self) -> Snapshot {
        let mut params = self.cfg.fingerprint();
        params.extend(self.est.state());
        Snapshot {
            epoch: self.done,
            loss: self.est.payoff.mean(),
            params,
        }
    }

    /// Rebuild a sweep from a checkpoint, validating that `cfg` describes
    /// the same distribution (bitwise fingerprint match) — execution knobs
    /// (workers/lanes/chunk) are free to differ.
    pub fn resume(cfg: RiskConfig, snap: &Snapshot) -> crate::Result<Self> {
        let fp = cfg.fingerprint();
        if snap.params.len() != RiskConfig::FP_LEN + RiskEstimators::STATE_LEN {
            return Err(crate::format_err!(
                "risk checkpoint has {} words, expected {}",
                snap.params.len(),
                RiskConfig::FP_LEN + RiskEstimators::STATE_LEN
            ));
        }
        // Version word first, with a version-specific message — a format
        // mismatch is a different failure than a knob mismatch.
        if snap.params[0].to_bits() != RiskConfig::SNAPSHOT_VERSION.to_bits() {
            return Err(crate::format_err!(
                "risk checkpoint has snapshot format version {:e}, this build reads version {:e}",
                snap.params[0],
                RiskConfig::SNAPSHOT_VERSION
            ));
        }
        for (i, (a, b)) in fp.iter().zip(snap.params.iter()).enumerate().skip(1) {
            if a.to_bits() != b.to_bits() {
                return Err(crate::format_err!(
                    "risk checkpoint was taken under a different configuration \
                     (fingerprint word {i}: {a:e} vs {b:e})"
                ));
            }
        }
        if snap.epoch > cfg.paths {
            return Err(crate::format_err!(
                "risk checkpoint has {} paths done, but the sweep only has {}",
                snap.epoch,
                cfg.paths
            ));
        }
        let est = RiskEstimators::from_state(&snap.params[RiskConfig::FP_LEN..])?;
        Ok(Self {
            cfg,
            est,
            done: snap.epoch,
        })
    }

    /// Advance by one chunk (clipped to `limit` and to the sweep's total),
    /// folding the chunk's payoffs in global path-index order. Returns the
    /// number of paths processed (0 when already at the limit).
    fn step_chunk_to(&mut self, limit: usize) -> usize {
        let limit = limit.min(self.cfg.paths);
        if self.done >= limit {
            return 0;
        }
        let n = self.cfg.chunk.min(limit - self.done);
        // Injection fires BEFORE any payoff is computed or folded: a
        // chunk that panics leaves `done` and the estimators exactly at
        // the previous chunk boundary, so the last checkpoint is always
        // consistent and a resume replays the killed chunk in full.
        self.cfg.fault.delay_point("risk.chunk");
        self.cfg.fault.panic_point("risk.chunk");
        let payoffs = chunk_payoffs(&self.cfg, self.done, n);
        for x in payoffs {
            self.est.push(x);
        }
        self.done += n;
        n
    }

    /// Run until `limit` paths are done (clipped to the sweep total) — the
    /// `--stop-after` entry point. Chunk boundaries never affect the
    /// estimates, so stopping here and [`Self::resume`]-ing later lands on
    /// exactly the uninterrupted run's state.
    pub fn run_to(&mut self, limit: usize) {
        while self.step_chunk_to(limit) > 0 {}
    }

    /// Run the whole sweep.
    pub fn run(&mut self) {
        self.run_to(self.cfg.paths);
    }

    /// [`Self::run_to`] with auto-checkpointing: after every `every`
    /// paths of progress (rounded up to chunk boundaries by `run_to`) the
    /// sweep state is written to `path` through the crash-safe
    /// [`atomic_write_with`](crate::fault::atomic_write_with), so a kill
    /// at any instant leaves a complete, resumable checkpoint at most
    /// `every` paths behind. Estimates are unaffected by the cadence —
    /// checkpointing only reads state — which is what makes a
    /// crash→resume run byte-identical to an uninterrupted one (the
    /// chaos-smoke CI gate).
    pub fn run_checkpointed(&mut self, limit: usize, every: usize, path: &str) -> crate::Result<()> {
        let limit = limit.min(self.cfg.paths);
        let every = every.max(1);
        let plan = self.cfg.fault.clone();
        while self.done < limit {
            let next = limit.min(self.done.saturating_add(every));
            self.run_to(next);
            crate::fault::atomic_write_with(&plan, path, &self.snapshot().to_text())
                .map_err(|e| crate::format_err!("cannot write risk checkpoint {path}: {e}"))?;
        }
        Ok(())
    }

    pub fn report(&self) -> RiskReport {
        RiskReport {
            scenario: self.cfg.scenario.name(),
            stepper: self.cfg.stepper.name(),
            paths_done: self.done,
            paths_total: self.cfg.paths,
            alpha: self.cfg.alpha,
            mean: self.est.payoff.mean(),
            variance: self.est.payoff.variance(),
            quantiles: QUANTILES
                .iter()
                .zip(self.est.quantiles.iter())
                .map(|(&p, q)| (p, q.estimate()))
                .collect(),
            var_loss: self.est.cvar_loss.var(),
            cvar_loss: self.est.cvar_loss.estimate(),
            min: self.est.min,
            max: self.est.max,
        }
    }
}

/// Compute payoffs for global path indices `start..start + n`, in index
/// order. Pure in `(cfg-distribution, start, n)`: the same indices yield
/// bitwise-identical payoffs at every worker/lane/chunk setting.
fn chunk_payoffs(cfg: &RiskConfig, start: usize, n: usize) -> Vec<f64> {
    let (seed, par) = (cfg.seed, cfg.parallelism);
    match cfg.scenario {
        RiskScenario::RoughBergomi => {
            let (t_end, fine) = (cfg.horizon, cfg.steps);
            parallel_map(par, n, |i| {
                let mut rng = path_stream(seed, (start + i) as u64);
                // n_obs = 1: [S_0, S_T] only — O(steps) transient per path.
                let p = simulate_price_path(VolModel::RoughBergomi, t_end, fine, 1, &mut rng);
                p[1].ln()
            })
        }
        RiskScenario::GbmPortfolio => {
            let model = GbmPortfolio::paper(cfg.dim);
            let h = cfg.horizon / cfg.steps as f64;
            match cfg.stepper {
                RiskStepper::Ees => {
                    // Raw (independent) increments: the field applies the
                    // correlation inside its combined evaluation.
                    let paths: Vec<BrownianPath> = parallel_map(par, n, |i| {
                        let mut rng = path_stream(seed, (start + i) as u64);
                        BrownianPath::sample(&mut rng, cfg.dim, cfg.steps, h)
                    });
                    let y0s: Vec<Vec<f64>> = (0..n).map(|_| vec![1.0; cfg.dim]).collect();
                    let st = LowStorageStepper::ees25();
                    let field = model.as_field();
                    let terms =
                        batch_terminal_lanes_par(&st, &field, 0.0, &y0s, &paths, par, cfg.lanes);
                    terms.iter().map(|y| GbmPortfolio::value(y)).collect()
                }
                RiskStepper::Milstein => {
                    // Same per-index noise stream as the EES arm (identical
                    // BrownianPath::sample consumption), correlated at the
                    // step via L·dw — the two arms estimate the same book.
                    let mi = Milstein::new();
                    let pool = WorkspacePool::new();
                    let correlate = |src: &[f64], dst: &mut [f64]| model.correlate(src, dst);
                    parallel_map(par, n, |i| {
                        let mut rng = path_stream(seed, (start + i) as u64);
                        let path = BrownianPath::sample(&mut rng, cfg.dim, cfg.steps, h);
                        let mut y = vec![1.0; cfg.dim];
                        let mut ws = pool.take();
                        mi.terminal_ws(&model, 0.0, &mut y, &path, &correlate, &mut ws);
                        pool.put(ws);
                        GbmPortfolio::value(&y)
                    })
                }
            }
        }
        RiskScenario::Kuramoto => {
            let params = KuramotoParams::paper(cfg.dim);
            let sp = TTorus::new(cfg.dim);
            let vf = params.as_field();
            let st = CfEes::ees25();
            let h = cfg.horizon / cfg.steps as f64;
            let scale = h.sqrt();
            let pool = WorkspacePool::new();
            parallel_map(par, n, |i| {
                let mut rng = path_stream(seed, (start + i) as u64);
                let dim = cfg.dim;
                let mut y = vec![0.0; 2 * dim];
                for v in y.iter_mut().take(dim) {
                    *v = rng.uniform_range(-std::f64::consts::PI, std::f64::consts::PI);
                }
                for v in y.iter_mut().skip(dim) {
                    *v = 0.5 * rng.normal();
                }
                // Streaming integration: per-step increments drawn on the
                // fly, no trajectory and no stored driver — O(N) state per
                // worker however many steps the horizon takes.
                let mut dw = vec![0.0; dim];
                let mut ws = pool.take();
                for s in 0..cfg.steps {
                    rng.fill_normal_scaled(scale, &mut dw);
                    st.step_ws(&sp, &vf, s as f64 * h, h, &dw, &mut y, &mut ws);
                }
                pool.put(ws);
                KuramotoParams::order_parameter(&y[..dim])
            })
        }
    }
}

/// A finished (or partial) sweep's estimates, renderable as a table or as
/// deterministic JSON.
#[derive(Clone, Debug)]
pub struct RiskReport {
    pub scenario: &'static str,
    pub stepper: &'static str,
    pub paths_done: usize,
    pub paths_total: usize,
    pub alpha: f64,
    pub mean: f64,
    pub variance: f64,
    /// `(level, estimate)` per [`QUANTILES`] entry.
    pub quantiles: Vec<(f64, f64)>,
    /// VaR_α of the loss (−payoff).
    pub var_loss: f64,
    /// CVaR_α of the loss.
    pub cvar_loss: f64,
    pub min: f64,
    pub max: f64,
}

/// Deterministic JSON float: `{:e}` prints the shortest round-trip form of
/// the exact bit pattern; non-finite values map to `null` so the output
/// stays valid JSON.
fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x:e}")
    } else {
        "null".into()
    }
}

impl RiskReport {
    /// Every headline estimate is finite (the `--assert-finite` gate).
    /// Variance needs two paths; everything else one.
    pub fn is_finite(&self) -> bool {
        self.mean.is_finite()
            && self.variance.is_finite()
            && self.quantiles.iter().all(|(_, v)| v.is_finite())
            && self.var_loss.is_finite()
            && self.cvar_loss.is_finite()
            && self.min.is_finite()
            && self.max.is_finite()
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(&["estimate", "value"]);
        let f = |x: f64| format!("{x:.6e}");
        t.row(&["mean payoff".into(), f(self.mean)]);
        t.row(&["variance".into(), f(self.variance)]);
        for (p, v) in &self.quantiles {
            t.row(&[format!("q{:02.0}", p * 100.0), f(*v)]);
        }
        t.row(&[format!("VaR[{}] (loss)", self.alpha), f(self.var_loss)]);
        t.row(&[format!("CVaR[{}] (loss)", self.alpha), f(self.cvar_loss)]);
        t.row(&["min".into(), f(self.min)]);
        t.row(&["max".into(), f(self.max)]);
        format!(
            "== ees risk: scenario '{}' ({} stepper, {}/{} paths) ==\n{}",
            self.scenario,
            self.stepper,
            self.paths_done,
            self.paths_total,
            t.render()
        )
    }

    /// Deterministic JSON (stable key order, bit-faithful `{:e}` floats, no
    /// wall-clock or environment fields) — two runs that are bitwise-equal
    /// produce byte-identical files, which is what the CI resume gate
    /// `diff`s.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"scenario\": \"{}\",\n", self.scenario));
        s.push_str(&format!("  \"stepper\": \"{}\",\n", self.stepper));
        s.push_str(&format!("  \"paths_done\": {},\n", self.paths_done));
        s.push_str(&format!("  \"paths_total\": {},\n", self.paths_total));
        s.push_str(&format!("  \"alpha\": {},\n", jnum(self.alpha)));
        s.push_str(&format!("  \"mean\": {},\n", jnum(self.mean)));
        s.push_str(&format!("  \"variance\": {},\n", jnum(self.variance)));
        for ((_, v), key) in self.quantiles.iter().zip(["q05", "q50", "q95"]) {
            s.push_str(&format!("  \"{key}\": {},\n", jnum(*v)));
        }
        s.push_str(&format!("  \"var_loss\": {},\n", jnum(self.var_loss)));
        s.push_str(&format!("  \"cvar_loss\": {},\n", jnum(self.cvar_loss)));
        s.push_str(&format!("  \"min\": {},\n", jnum(self.min)));
        s.push_str(&format!("  \"max\": {}\n", jnum(self.max)));
        s.push('}');
        s.push('\n');
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_text(extra: &str) -> RiskConfig {
        let text = format!("[risk]\npaths = 64\nsteps = 8\nchunk = 16\nseed = 7\n{extra}\n[exec]\nparallelism = 2\n");
        RiskConfig::from_config(&Config::parse(&text).unwrap()).unwrap()
    }

    #[test]
    fn config_defaults_and_overrides() {
        let c = cfg_text("");
        assert_eq!(c.scenario, RiskScenario::RoughBergomi);
        assert_eq!(c.stepper, RiskStepper::Ees);
        assert_eq!((c.paths, c.steps, c.chunk, c.seed), (64, 8, 16, 7));
        assert_eq!(c.parallelism, 2);
        assert_eq!(c.checkpoint_every, 0);
        assert!(!c.fault.is_armed());
        let c = cfg_text("checkpoint_every = 500");
        assert_eq!(c.checkpoint_every, 500);
        let c = cfg_text("scenario = \"gbm_portfolio\"\nstepper = \"milstein\"\ndim = 4");
        assert_eq!(c.scenario, RiskScenario::GbmPortfolio);
        assert_eq!(c.stepper, RiskStepper::Milstein);
        assert_eq!(c.dim, 4);
    }

    #[test]
    fn milstein_needs_componentwise_diffusion() {
        let text = "[risk]\nscenario = \"kuramoto\"\nstepper = \"milstein\"\n";
        let err = RiskConfig::from_config(&Config::parse(text).unwrap()).unwrap_err();
        assert!(format!("{err}").contains("componentwise"));
    }

    #[test]
    fn bad_knobs_are_rejected() {
        for bad in [
            "[risk]\nscenario = \"heat-death\"\n",
            "[risk]\npaths = 0\n",
            "[risk]\nalpha = 1.5\n",
            "[risk]\nhorizon = -1.0\n",
        ] {
            assert!(RiskConfig::from_config(&Config::parse(bad).unwrap()).is_err());
        }
    }

    #[test]
    fn chunk_size_is_bitwise_invisible() {
        let a = {
            let mut s = RiskSweep::new(cfg_text(""));
            s.run();
            s
        };
        let b = {
            let mut s = RiskSweep::new(cfg_text("chunk = 5"));
            s.run();
            s
        };
        assert_eq!(a.done(), 64);
        let bits = |s: &RiskSweep| {
            s.estimators()
                .state()
                .into_iter()
                .map(f64::to_bits)
                .collect::<Vec<_>>()
        };
        assert_eq!(bits(&a), bits(&b));
        assert!(a.report().is_finite());
    }

    #[test]
    fn resume_rejects_foreign_checkpoints() {
        let mut s = RiskSweep::new(cfg_text(""));
        s.run_to(16);
        let snap = s.snapshot();
        assert_eq!(snap.epoch, 16);
        // Different seed → different distribution → refused.
        let other = cfg_text("seed = 8");
        let err = RiskSweep::resume(other, &snap).unwrap_err();
        assert!(format!("{err}").contains("different configuration"));
        // Same distribution at different exec knobs → accepted.
        let same = cfg_text("chunk = 3");
        assert!(RiskSweep::resume(same, &snap).is_ok());
    }

    #[test]
    fn resume_rejects_bumped_snapshot_version() {
        let mut s = RiskSweep::new(cfg_text(""));
        s.run_to(16);
        let mut snap = s.snapshot();
        // Word 0 is the format version; a snapshot from a future (or past)
        // layout must be refused with a version message, not a generic
        // knob mismatch — the words after the fingerprint would otherwise
        // be misinterpreted as estimator state.
        snap.params[0] = RiskConfig::SNAPSHOT_VERSION + 1.0;
        let err = RiskSweep::resume(cfg_text(""), &snap).unwrap_err();
        assert!(format!("{err}").contains("version"));
        // Untampered snapshot of the current version resumes fine.
        assert!(RiskSweep::resume(cfg_text(""), &s.snapshot()).is_ok());
    }
}
