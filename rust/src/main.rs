//! `ees` — command-line launcher for the EES Neural-SDE framework.
//!
//! Subcommands map one-to-one onto the paper's tables and figures (see
//! DESIGN.md §4 for the index). `--full` switches from the smoke
//! configuration to paper scale; `--out FILE` tees the report to a file.
//!
//! ```text
//! ees stability            # Figure 2 (+ --render for ASCII domains)
//! ees ms-stability         # Figure 3
//! ees ou                   # Table 1 / Figure 4
//! ees stochvol [--model M] # Tables 2 & 8
//! ees kuramoto             # Table 3
//! ees kuramoto-memory      # Figure 5b / Table 13
//! ees sphere               # Table 4
//! ees sphere-memory        # Figure 6 / Table 14
//! ees gbm                  # Table 7 / Figures 10-11
//! ees md                   # Table 9 / Figure 13
//! ees adjoint-fidelity     # Table 12
//! ees memory-t7            # Figure 1 / Table 15
//! ees convergence          # Figure 7
//! ees cf-convergence       # Figure 8
//! ees ees27                # Figure 9
//! ees runtime-smoke        # PJRT artifact load/execute check
//! ees all                  # everything (smoke scale)
//! ```

use ees::experiments::{self, Scale};
use ees::models::stochvol::VolModel;

struct Args {
    cmd: String,
    full: bool,
    render: bool,
    out: Option<String>,
    model: Option<String>,
    steps: Vec<usize>,
}

fn parse_args() -> Args {
    let mut args = Args {
        cmd: String::new(),
        full: false,
        render: false,
        out: None,
        model: None,
        steps: vec![],
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--full" => args.full = true,
            "--render" => args.render = true,
            "--out" => args.out = it.next(),
            "--model" => args.model = it.next(),
            "--steps" => {
                if let Some(s) = it.next() {
                    args.steps = s
                        .split(',')
                        .filter_map(|x| x.trim().parse().ok())
                        .collect();
                }
            }
            other if args.cmd.is_empty() && !other.starts_with('-') => {
                args.cmd = other.to_string();
            }
            other => {
                eprintln!("unknown argument: {other}");
                std::process::exit(2);
            }
        }
    }
    args
}

fn vol_model(name: &str) -> Option<VolModel> {
    VolModel::all()
        .into_iter()
        .find(|m| m.name().to_lowercase().contains(&name.to_lowercase()))
}

fn main() {
    let args = parse_args();
    let scale = if args.full { Scale::Full } else { Scale::Smoke };
    let default_steps = |smoke: &[usize], full: &[usize]| -> Vec<usize> {
        if !args.steps.is_empty() {
            args.steps.clone()
        } else if args.full {
            full.to_vec()
        } else {
            smoke.to_vec()
        }
    };
    let report = match args.cmd.as_str() {
        "stability" => experiments::fig2::run(args.render),
        "ms-stability" => experiments::fig3::run(if args.full { 20000 } else { 2000 }),
        "ou" => experiments::tab1::run(scale),
        "stochvol" => {
            let models: Vec<VolModel> = match &args.model {
                Some(m) => vec![vol_model(m).unwrap_or_else(|| {
                    eprintln!("unknown model {m}");
                    std::process::exit(2)
                })],
                None => {
                    if args.full {
                        VolModel::all().to_vec()
                    } else {
                        vec![VolModel::RoughBergomi, VolModel::BlackScholes]
                    }
                }
            };
            experiments::tab2::run(scale, &models)
        }
        "kuramoto" => experiments::tab3::run(scale),
        "kuramoto-memory" => {
            let steps = default_steps(&[50, 100, 200, 500], &[50, 100, 200, 500, 1000, 2000, 5000]);
            experiments::tab3::run_memory(if args.full { 1000 } else { 16 }, &steps)
        }
        "sphere" => experiments::tab4::run(scale),
        "sphere-memory" => {
            let steps = default_steps(&[50, 200, 800], &[50, 200, 800, 2000, 5000]);
            experiments::tab4::run_memory(if args.full { 16 } else { 6 }, &steps)
        }
        "gbm" => experiments::tab7::run(scale),
        "md" => experiments::tab9::run(scale),
        "adjoint-fidelity" => experiments::tab12::run(scale),
        "memory-t7" => {
            let steps = default_steps(
                &[5, 20, 100, 400],
                &[5, 10, 20, 50, 100, 200, 400, 800, 2000, 5000, 10000],
            );
            experiments::fig1::run(if args.full { 64 } else { 4 }, &steps)
        }
        "convergence" => experiments::fig7::run(scale),
        "cf-convergence" => experiments::fig8::run(scale),
        "ees27" => experiments::fig9::run(scale),
        "runtime-smoke" => runtime_smoke(),
        "all" => {
            let mut all = String::new();
            all.push_str(&experiments::fig2::run(false));
            all.push('\n');
            all.push_str(&experiments::fig3::run(2000));
            all.push('\n');
            all.push_str(&experiments::fig1::run(4, &[5, 20, 100, 400]));
            all.push('\n');
            all.push_str(&experiments::tab1::run(scale));
            all.push('\n');
            all.push_str(&experiments::tab2::run(scale, &[VolModel::RoughBergomi]));
            all.push('\n');
            all.push_str(&experiments::tab3::run(scale));
            all.push('\n');
            all.push_str(&experiments::tab4::run(scale));
            all.push('\n');
            all.push_str(&experiments::tab7::run(scale));
            all.push('\n');
            all.push_str(&experiments::tab9::run(scale));
            all.push('\n');
            all.push_str(&experiments::tab12::run(scale));
            all.push('\n');
            all.push_str(&experiments::fig7::run(scale));
            all.push('\n');
            all.push_str(&experiments::fig8::run(scale));
            all.push('\n');
            all.push_str(&experiments::fig9::run(scale));
            all
        }
        "" | "help" | "--help" | "-h" => {
            eprintln!("usage: ees <command> [--full] [--render] [--out FILE] [--model NAME] [--steps a,b,c]");
            eprintln!("commands: stability ms-stability ou stochvol kuramoto kuramoto-memory");
            eprintln!("          sphere sphere-memory gbm md adjoint-fidelity memory-t7");
            eprintln!("          convergence cf-convergence ees27 runtime-smoke all");
            std::process::exit(0);
        }
        other => {
            eprintln!("unknown command: {other} (try `ees help`)");
            std::process::exit(2);
        }
    };
    println!("{report}");
    if let Some(path) = args.out {
        if let Err(e) = std::fs::write(&path, &report) {
            eprintln!("failed to write {path}: {e}");
            std::process::exit(1);
        }
        eprintln!("report written to {path}");
    }
}

/// PJRT smoke: load the AOT EES-step artifact and run one batch step.
fn runtime_smoke() -> String {
    use ees::runtime::CompiledModule;
    let dir = std::path::PathBuf::from(
        std::env::var("EES_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let path = dir.join("ees_step.hlo.txt");
    if !path.exists() {
        return format!(
            "artifact {path:?} not found — run `make artifacts` first (python build path)"
        );
    }
    let m = match CompiledModule::load_cpu(&path) {
        Ok(m) => m,
        Err(e) => return format!("PJRT load failed: {e:#}"),
    };
    let (b, d) = (8usize, 4usize);
    let y: Vec<f32> = (0..b * d).map(|i| i as f32 * 0.01).collect();
    let dw = vec![0.0f32; b * d];
    let h = [0.05f32];
    match m.run_f32(&[(&y, &[b, d]), (&dw, &[b, d]), (&h, &[])]) {
        Ok(out) => format!(
            "PJRT OK: {} -> {} outputs, first row {:?}",
            m.name,
            out.len(),
            &out[0][..d]
        ),
        Err(e) => format!("PJRT execute failed: {e:#}"),
    }
}
