//! `ees` — command-line launcher for the EES Neural-SDE framework.
//!
//! Subcommands map one-to-one onto the paper's tables and figures (see
//! DESIGN.md §4 for the index). `--full` switches from the smoke
//! configuration to paper scale; `--out FILE` tees the report to a file.
//!
//! ```text
//! ees stability            # Figure 2 (+ --render for ASCII domains)
//! ees ms-stability         # Figure 3
//! ees ou                   # Table 1 / Figure 4
//! ees stochvol [--model M] # Tables 2 & 8
//! ees kuramoto             # Table 3
//! ees kuramoto-memory      # Figure 5b / Table 13
//! ees sphere               # Table 4
//! ees sphere-memory        # Figure 6 / Table 14
//! ees gbm                  # Table 7 / Figures 10-11
//! ees md                   # Table 9 / Figure 13
//! ees adjoint-fidelity     # Table 12
//! ees memory-t7            # Figure 1 / Table 15
//! ees convergence          # Figure 7
//! ees cf-convergence       # Figure 8
//! ees ees27                # Figure 9
//! ees runtime-smoke        # PJRT artifact load/execute check
//! ees all                  # everything (smoke scale)
//! ees train --config F     # training engine: run a registered scenario
//! ees risk --config F      # streaming Monte Carlo risk sweep
//! ees serve [--addr A]     # streaming simulation service (JSON over TCP)
//! ```
//!
//! `ees train` reads a `[train]` config section (scenario, epochs, batch,
//! optimiser, schedule, seed — see `ees::train::TrainConfig::from_config`),
//! runs it through the unified training engine and prints the per-epoch
//! summary. `--ledger OUT.json` additionally writes the run's per-epoch
//! `TrainLedger` JSON once the run finishes (library users wanting rows
//! as they happen attach `TrainLedger` as a streaming `Callback` instead);
//! `--max-final-loss X` / `--max-loss-ratio R` (terminal 5-epoch window
//! vs first 5-epoch window) / `--assert-improves` turn the run into a CI
//! smoke gate (non-zero exit on failure).

use ees::config::Config;
use ees::experiments::{self, Scale};
use ees::models::stochvol::VolModel;
use ees::train::{scenarios, TrainLedger};

struct Args {
    cmd: String,
    full: bool,
    render: bool,
    out: Option<String>,
    model: Option<String>,
    steps: Vec<usize>,
    config: Option<String>,
    scenario: Option<String>,
    ledger: Option<String>,
    max_final_loss: Option<f64>,
    max_loss_ratio: Option<f64>,
    assert_improves: bool,
    paths: Option<usize>,
    checkpoint: Option<String>,
    checkpoint_every: Option<usize>,
    resume: Option<String>,
    stop_after: Option<usize>,
    assert_finite: bool,
    addr: Option<String>,
}

fn parse_args() -> Args {
    let mut args = Args {
        cmd: String::new(),
        full: false,
        render: false,
        out: None,
        model: None,
        steps: vec![],
        config: None,
        scenario: None,
        ledger: None,
        max_final_loss: None,
        max_loss_ratio: None,
        assert_improves: false,
        paths: None,
        checkpoint: None,
        checkpoint_every: None,
        resume: None,
        stop_after: None,
        assert_finite: false,
        addr: None,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--full" => args.full = true,
            "--render" => args.render = true,
            "--out" => args.out = it.next(),
            "--model" => args.model = it.next(),
            "--config" => args.config = it.next(),
            "--scenario" => args.scenario = it.next(),
            "--ledger" => args.ledger = it.next(),
            "--max-final-loss" => {
                let raw = it.next().unwrap_or_default();
                match raw.parse() {
                    Ok(v) => args.max_final_loss = Some(v),
                    Err(_) => {
                        // A malformed threshold must fail loudly: silently
                        // dropping it would vacuously green-light the CI
                        // smoke gate.
                        eprintln!("--max-final-loss: not a number: '{raw}'");
                        std::process::exit(2);
                    }
                }
            }
            "--max-loss-ratio" => {
                let raw = it.next().unwrap_or_default();
                match raw.parse() {
                    Ok(v) => args.max_loss_ratio = Some(v),
                    Err(_) => {
                        eprintln!("--max-loss-ratio: not a number: '{raw}'");
                        std::process::exit(2);
                    }
                }
            }
            "--assert-improves" => args.assert_improves = true,
            "--addr" => args.addr = it.next(),
            "--assert-finite" => args.assert_finite = true,
            "--checkpoint" => args.checkpoint = it.next(),
            "--checkpoint-every" => {
                let raw = it.next().unwrap_or_default();
                match raw.parse() {
                    Ok(v) => args.checkpoint_every = Some(v),
                    Err(_) => {
                        eprintln!("--checkpoint-every: not a count: '{raw}'");
                        std::process::exit(2);
                    }
                }
            }
            "--resume" => args.resume = it.next(),
            "--paths" => {
                let raw = it.next().unwrap_or_default();
                match raw.parse() {
                    Ok(v) => args.paths = Some(v),
                    Err(_) => {
                        eprintln!("--paths: not a count: '{raw}'");
                        std::process::exit(2);
                    }
                }
            }
            "--stop-after" => {
                let raw = it.next().unwrap_or_default();
                match raw.parse() {
                    Ok(v) => args.stop_after = Some(v),
                    Err(_) => {
                        eprintln!("--stop-after: not a count: '{raw}'");
                        std::process::exit(2);
                    }
                }
            }
            "--steps" => {
                if let Some(s) = it.next() {
                    args.steps = s
                        .split(',')
                        .filter_map(|x| x.trim().parse().ok())
                        .collect();
                }
            }
            other if args.cmd.is_empty() && !other.starts_with('-') => {
                args.cmd = other.to_string();
            }
            other => {
                eprintln!("unknown argument: {other}");
                std::process::exit(2);
            }
        }
    }
    args
}

fn vol_model(name: &str) -> Option<VolModel> {
    VolModel::all()
        .into_iter()
        .find(|m| m.name().to_lowercase().contains(&name.to_lowercase()))
}

fn main() {
    let args = parse_args();
    let scale = if args.full { Scale::Full } else { Scale::Smoke };
    let default_steps = |smoke: &[usize], full: &[usize]| -> Vec<usize> {
        if !args.steps.is_empty() {
            args.steps.clone()
        } else if args.full {
            full.to_vec()
        } else {
            smoke.to_vec()
        }
    };
    let report = match args.cmd.as_str() {
        "stability" => experiments::fig2::run(args.render),
        "ms-stability" => experiments::fig3::run(if args.full { 20000 } else { 2000 }),
        "ou" => experiments::tab1::run(scale),
        "stochvol" => {
            let models: Vec<VolModel> = match &args.model {
                Some(m) => vec![vol_model(m).unwrap_or_else(|| {
                    eprintln!("unknown model {m}");
                    std::process::exit(2)
                })],
                None => {
                    if args.full {
                        VolModel::all().to_vec()
                    } else {
                        vec![VolModel::RoughBergomi, VolModel::BlackScholes]
                    }
                }
            };
            experiments::tab2::run(scale, &models)
        }
        "kuramoto" => experiments::tab3::run(scale),
        "kuramoto-memory" => {
            let steps = default_steps(&[50, 100, 200, 500], &[50, 100, 200, 500, 1000, 2000, 5000]);
            experiments::tab3::run_memory(if args.full { 1000 } else { 16 }, &steps)
        }
        "sphere" => experiments::tab4::run(scale),
        "sphere-memory" => {
            let steps = default_steps(&[50, 200, 800], &[50, 200, 800, 2000, 5000]);
            experiments::tab4::run_memory(if args.full { 16 } else { 6 }, &steps)
        }
        "gbm" => experiments::tab7::run(scale),
        "md" => experiments::tab9::run(scale),
        "adjoint-fidelity" => experiments::tab12::run(scale),
        "memory-t7" => {
            let steps = default_steps(
                &[5, 20, 100, 400],
                &[5, 10, 20, 50, 100, 200, 400, 800, 2000, 5000, 10000],
            );
            experiments::fig1::run(if args.full { 64 } else { 4 }, &steps)
        }
        "convergence" => experiments::fig7::run(scale),
        "cf-convergence" => experiments::fig8::run(scale),
        "ees27" => experiments::fig9::run(scale),
        "runtime-smoke" => runtime_smoke(),
        "train" => run_train(&args),
        "risk" => run_risk(&args),
        "serve" => run_serve(&args),
        "all" => {
            let mut all = String::new();
            all.push_str(&experiments::fig2::run(false));
            all.push('\n');
            all.push_str(&experiments::fig3::run(2000));
            all.push('\n');
            all.push_str(&experiments::fig1::run(4, &[5, 20, 100, 400]));
            all.push('\n');
            all.push_str(&experiments::tab1::run(scale));
            all.push('\n');
            all.push_str(&experiments::tab2::run(scale, &[VolModel::RoughBergomi]));
            all.push('\n');
            all.push_str(&experiments::tab3::run(scale));
            all.push('\n');
            all.push_str(&experiments::tab4::run(scale));
            all.push('\n');
            all.push_str(&experiments::tab7::run(scale));
            all.push('\n');
            all.push_str(&experiments::tab9::run(scale));
            all.push('\n');
            all.push_str(&experiments::tab12::run(scale));
            all.push('\n');
            all.push_str(&experiments::fig7::run(scale));
            all.push('\n');
            all.push_str(&experiments::fig8::run(scale));
            all.push('\n');
            all.push_str(&experiments::fig9::run(scale));
            all
        }
        "" | "help" | "--help" | "-h" => {
            eprintln!("usage: ees <command> [--full] [--render] [--out FILE] [--model NAME] [--steps a,b,c]");
            eprintln!("commands: stability ms-stability ou stochvol kuramoto kuramoto-memory");
            eprintln!("          sphere sphere-memory gbm md adjoint-fidelity memory-t7");
            eprintln!("          convergence cf-convergence ees27 runtime-smoke train risk serve all");
            eprintln!(
                "train:    ees train --config FILE [--scenario {}] [--ledger OUT.json]",
                ees::train::scenarios::NAMES.join("|")
            );
            eprintln!("                    [--max-final-loss X] [--max-loss-ratio R] [--assert-improves]");
            eprintln!(
                "risk:     ees risk --config FILE [--scenario {}] [--paths N]",
                ees::risk::NAMES.join("|")
            );
            eprintln!("                   [--stop-after N] [--checkpoint F] [--checkpoint-every K]");
            eprintln!("                   [--resume F] [--ledger OUT.json] [--assert-finite]");
            eprintln!("serve:    ees serve [--config FILE] [--addr HOST:PORT]   (default 127.0.0.1:8787)");
            eprintln!("                    newline-delimited JSON requests, e.g.");
            eprintln!("                    {{\"id\":1,\"scenario\":\"ou\",\"workload\":\"price\",\"paths\":32,\"seed\":7}}");
            std::process::exit(0);
        }
        other => {
            eprintln!("unknown command: {other} (try `ees help`)");
            std::process::exit(2);
        }
    };
    println!("{report}");
    if let Some(path) = args.out {
        if let Err(e) = ees::fault::atomic_write(&path, &report) {
            eprintln!("failed to write {path}: {e}");
            std::process::exit(1);
        }
        eprintln!("report written to {path}");
    }
}

/// `ees train`: run a registered training scenario from a config file
/// through the unified training engine (`ees::train`). Exits non-zero when
/// the scenario is unknown, the config is malformed, or a smoke assertion
/// (`--max-final-loss`, `--assert-improves`) fails.
fn run_train(args: &Args) -> String {
    let mut cfg = match &args.config {
        Some(path) => match Config::from_file(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("ees train: {e}");
                std::process::exit(2);
            }
        },
        None => Config::default(),
    };
    if let Some(name) = &args.scenario {
        cfg.values.insert(
            "train.scenario".into(),
            ees::config::Value::Str(name.clone()),
        );
    }
    let run = match scenarios::run_scenario(&cfg) {
        Ok(run) => run,
        Err(e) => {
            eprintln!("ees train: {e}");
            std::process::exit(2);
        }
    };
    if let Some(path) = &args.ledger {
        let json = TrainLedger::from_log(&run.scenario, &run.log).to_json();
        if let Err(e) = ees::fault::atomic_write(path, &json) {
            eprintln!("failed to write ledger {path}: {e}");
            std::process::exit(1);
        }
        eprintln!("train ledger written to {path}");
    }
    // Smoke-gate assertions (CI train-smoke): print the summary first so a
    // failing run still shows its loss curve.
    let terminal = run.log.terminal_loss();
    let mut failures = Vec::new();
    if run.log.diverged {
        failures.push("run diverged (non-finite loss or gradient)".to_string());
    }
    if let Some(max) = args.max_final_loss {
        let below = terminal < max;
        if !below {
            failures.push(format!("final loss {terminal} not below threshold {max}"));
        }
    }
    if args.assert_improves {
        let first = run.log.history.first().map(|m| m.loss).unwrap_or(f64::NAN);
        let improved = terminal < first;
        if !improved {
            failures.push(format!("final loss {terminal} did not improve on epoch 0 ({first})"));
        }
    }
    if let Some(ratio) = args.max_loss_ratio {
        // Relative improvement gate on 5-epoch window means (the same
        // smoothing as the golden curves in rust/tests/trainer.rs, which
        // this band is derived from): terminal window <= ratio x first
        // window.
        let hist = &run.log.history;
        let w = hist.len().min(5);
        if hist.is_empty() {
            failures.push("no epochs ran — cannot evaluate --max-loss-ratio".to_string());
        } else {
            let first: f64 = hist[..w].iter().map(|m| m.loss).sum::<f64>() / w as f64;
            let last: f64 = hist[hist.len() - w..].iter().map(|m| m.loss).sum::<f64>() / w as f64;
            // NaN-safe: a non-finite window must fail the gate too.
            let ok = last <= ratio * first;
            if !ok {
                failures.push(format!(
                    "terminal loss window {last} above {ratio} x first window {first}"
                ));
            }
        }
    }
    if !failures.is_empty() {
        println!("{}", run.summary);
        for f in &failures {
            eprintln!("ees train: FAILED: {f}");
        }
        std::process::exit(1);
    }
    run.summary
}

/// `ees risk`: run (or resume) a streaming Monte Carlo risk sweep from a
/// `[risk]` config section (`ees::risk`). `--stop-after N` halts the sweep
/// after N paths (for mid-sweep checkpointing), `--checkpoint F` writes the
/// bit-exact snapshot text, `--checkpoint-every K` additionally
/// checkpoints to F after every K paths *during* the run (atomic
/// temp+rename writes, so a kill at any instant leaves a complete
/// resumable file), `--resume F` continues from one, `--ledger OUT.json`
/// writes the deterministic estimate JSON and `--assert-finite` turns the
/// run into a CI gate. Exits 2 on configuration errors, 1 on gate/IO
/// failures.
fn run_risk(args: &Args) -> String {
    use ees::risk::{RiskConfig, RiskSweep};
    use ees::train::Snapshot;
    let mut cfg = match &args.config {
        Some(path) => match Config::from_file(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("ees risk: {e}");
                std::process::exit(2);
            }
        },
        None => Config::default(),
    };
    if let Some(name) = &args.scenario {
        cfg.values.insert(
            "risk.scenario".into(),
            ees::config::Value::Str(name.clone()),
        );
    }
    if let Some(paths) = args.paths {
        cfg.values.insert(
            "risk.paths".into(),
            ees::config::Value::Int(paths as i64),
        );
    }
    let rc = match RiskConfig::from_config(&cfg) {
        Ok(rc) => rc,
        Err(e) => {
            eprintln!("ees risk: {e}");
            std::process::exit(2);
        }
    };
    let mut sweep = match &args.resume {
        Some(path) => {
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("ees risk: cannot read checkpoint {path}: {e}");
                    std::process::exit(2);
                }
            };
            let snap = match Snapshot::from_text(&text) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("ees risk: bad checkpoint {path}: {e}");
                    std::process::exit(2);
                }
            };
            match RiskSweep::resume(rc, &snap) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("ees risk: {e}");
                    std::process::exit(2);
                }
            }
        }
        None => RiskSweep::new(rc),
    };
    let limit = args.stop_after.unwrap_or(usize::MAX);
    let every = args.checkpoint_every.unwrap_or(sweep.cfg().checkpoint_every);
    let plan = sweep.cfg().fault.clone();
    if every > 0 {
        let Some(path) = args.checkpoint.clone() else {
            eprintln!("ees risk: --checkpoint-every needs --checkpoint FILE to write to");
            std::process::exit(2);
        };
        if let Err(e) = sweep.run_checkpointed(limit, every, &path) {
            eprintln!("ees risk: {e}");
            std::process::exit(1);
        }
    } else {
        sweep.run_to(limit);
    }
    if let Some(path) = &args.checkpoint {
        if let Err(e) = ees::fault::atomic_write_with(&plan, path, &sweep.snapshot().to_text()) {
            eprintln!("failed to write checkpoint {path}: {e}");
            std::process::exit(1);
        }
        eprintln!(
            "risk checkpoint written to {path} ({} / {} paths done)",
            sweep.done(),
            sweep.cfg().paths
        );
    }
    let report = sweep.report();
    if let Some(path) = &args.ledger {
        if let Err(e) = ees::fault::atomic_write_with(&plan, path, &report.to_json()) {
            eprintln!("failed to write ledger {path}: {e}");
            std::process::exit(1);
        }
        eprintln!("risk ledger written to {path}");
    }
    if args.assert_finite && !report.is_finite() {
        println!("{}", report.render());
        eprintln!("ees risk: FAILED: non-finite estimate in the report");
        std::process::exit(1);
    }
    report.render()
}

/// `ees serve`: run the streaming simulation service (`ees::serve`) —
/// build the scenario registry from the `[serve.*]` config sections, start
/// the coalescing worker pool, and accept newline-delimited JSON requests
/// on `--addr` (default `127.0.0.1:8787`) until killed. Exits 2 on
/// configuration errors, 1 if the listener dies.
fn run_serve(args: &Args) -> String {
    use ees::serve::{serve_tcp, Registry, ServeConfig, Server};
    use std::sync::Arc;
    let cfg = match &args.config {
        Some(path) => match Config::from_file(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("ees serve: {e}");
                std::process::exit(2);
            }
        },
        None => Config::default(),
    };
    let sc = match ServeConfig::from_config(&cfg) {
        Ok(sc) => sc,
        Err(e) => {
            eprintln!("ees serve: {e}");
            std::process::exit(2);
        }
    };
    let registry = match Registry::from_config(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("ees serve: {e}");
            std::process::exit(2);
        }
    };
    let addr = args.addr.clone().unwrap_or_else(|| "127.0.0.1:8787".into());
    eprintln!(
        "ees serve: {} scenarios ({}), {} workers, lanes {}, coalesce {}, queue depth {}, window {}us, listening on {addr}",
        registry.names().len(),
        registry.names().join(", "),
        sc.workers,
        sc.lanes,
        sc.coalesce,
        sc.queue_depth,
        sc.window_us,
    );
    let server = Arc::new(Server::start(registry, sc));
    if let Err(e) = serve_tcp(server, &addr) {
        eprintln!("ees serve: {e}");
        std::process::exit(1);
    }
    String::new()
}

/// PJRT smoke: load the AOT EES-step artifact and run one batch step.
fn runtime_smoke() -> String {
    use ees::runtime::CompiledModule;
    let dir = std::path::PathBuf::from(
        std::env::var("EES_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let path = dir.join("ees_step.hlo.txt");
    if !path.exists() {
        return format!(
            "artifact {path:?} not found — run `make artifacts` first (python build path)"
        );
    }
    let m = match CompiledModule::load_cpu(&path) {
        Ok(m) => m,
        Err(e) => return format!("PJRT load failed: {e:#}"),
    };
    let (b, d) = (8usize, 4usize);
    let y: Vec<f32> = (0..b * d).map(|i| i as f32 * 0.01).collect();
    let dw = vec![0.0f32; b * d];
    let h = [0.05f32];
    match m.run_f32(&[(&y, &[b, d]), (&dw, &[b, d]), (&h, &[])]) {
        Ok(out) => format!(
            "PJRT OK: {} -> {} outputs, first row {:?}",
            m.name,
            out.len(),
            &out[0][..d]
        ),
        Err(e) => format!("PJRT execute failed: {e:#}"),
    }
}
