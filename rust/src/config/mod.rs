//! Minimal configuration system (no external crates are available in the
//! offline build, so this implements the TOML subset the experiment configs
//! use: `[sections]`, `key = value` with strings, bools, integers, floats
//! and flat numeric arrays, plus `#` comments), plus the process-wide
//! execution knobs ([`default_parallelism`]).

use std::collections::BTreeMap;
use std::sync::OnceLock;

/// Default worker count for the parallel batch engine
/// ([`crate::coordinator::parallel`]).
///
/// Resolution order, cached for the process lifetime:
/// 1. the `EES_PARALLELISM` environment variable (clamped to ≥ 1);
/// 2. [`std::thread::available_parallelism`];
/// 3. `1` (sequential) when neither is available.
///
/// Per-call overrides go through the coordinator's `*_par` entry points;
/// [`Config::parallelism`] reads the `[exec] parallelism` key for harnesses
/// that want to pass a config-file value there.
pub fn default_parallelism() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        if let Ok(v) = std::env::var("EES_PARALLELISM") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Default lane-group width for the lane-blocked batch engine
/// ([`crate::coordinator`]): how many samples a worker steps together in
/// structure-of-arrays layout, turning per-sample matvecs into blocked
/// matmuls. Results are **bitwise-identical at every lane count** (pinned
/// by `rust/tests/determinism.rs`) — this is a pure performance knob.
///
/// Resolution order, cached for the process lifetime:
/// 1. the `EES_LANES` environment variable (clamped to
///    `1..=`[`crate::linalg::MAX_LANES`]);
/// 2. `8` — wide enough that an MLP layer's lane matmul amortises the
///    weight-row traffic, small enough that lane blocks stay in L1.
///
/// Per-call overrides go through the coordinator's `*_lanes` entry points;
/// [`Config::lanes`] reads the `[exec] lanes` key for config-driven
/// harnesses.
pub fn default_lanes() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        if let Ok(v) = std::env::var("EES_LANES") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.clamp(1, crate::linalg::MAX_LANES);
            }
        }
        8
    })
}

/// Default for the SIMD kernel switch ([`crate::linalg::simd_enabled`]):
/// whether the hot linalg/NN kernels dispatch to their explicit-width
/// SIMD variants (feature `simd`) instead of the scalar reference kernels.
///
/// Resolution order, cached for the process lifetime:
/// 1. the `EES_SIMD` environment variable (`1`/`true`/`on`/`yes` → on,
///    `0`/`false`/`off`/`no` → off);
/// 2. `false` — the scalar kernels stay the default because they define
///    the crate's bitwise determinism contract (one float-op order shared
///    by every GEMV/GEMM path); the SIMD variants reassociate the
///    reductions and are therefore only tolerance-equal (see
///    `docs/ARCHITECTURE.md` §SIMD kernels & the determinism contract).
///
/// Without the `simd` cargo feature this knob is inert:
/// [`crate::linalg::simd_enabled`] is compile-time `false`. Process-wide
/// overrides go through [`crate::linalg::set_simd`]; [`Config::simd`]
/// reads the `[exec] simd` key for config-driven harnesses.
pub fn default_simd() -> bool {
    static CACHE: OnceLock<bool> = OnceLock::new();
    *CACHE.get_or_init(|| {
        if let Ok(v) = std::env::var("EES_SIMD") {
            return matches!(
                v.trim().to_ascii_lowercase().as_str(),
                "1" | "true" | "on" | "yes"
            );
        }
        false
    })
}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    Array(Vec<f64>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed config: `section.key` → value (top-level keys use section "").
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, parse_value(v.trim(), lineno + 1)?);
        }
        Ok(Self { values })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn from_file(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::parse(&text)
    }

    /// Worker count for the parallel batch engine: the `[exec] parallelism`
    /// key when present, otherwise the process default
    /// ([`default_parallelism`]). A value of 0 or 1 means sequential. The
    /// value takes effect when handed to one of the coordinator's `*_par`
    /// entry points — the plain-named wrappers only consult the process
    /// default.
    pub fn parallelism(&self) -> usize {
        self.usize_or("exec.parallelism", default_parallelism())
    }

    /// Lane-group width for the lane-blocked batch engine: the
    /// `[exec] lanes` key when present (clamped to
    /// `1..=`[`crate::linalg::MAX_LANES`]), otherwise the process default
    /// ([`default_lanes`]). A value of 1 means per-sample stepping. Like
    /// the worker count, this is a pure perf knob — results are
    /// bitwise-identical at every value.
    pub fn lanes(&self) -> usize {
        self.usize_or("exec.lanes", default_lanes())
            .clamp(1, crate::linalg::MAX_LANES)
    }

    /// SIMD kernel switch: the `[exec] simd` key when present, otherwise
    /// the process default ([`default_simd`], i.e. the `EES_SIMD` env
    /// var). Unlike the worker/lane knobs this is **not** bitwise-neutral:
    /// the SIMD kernels reassociate reductions, so turning it on trades
    /// the bitwise determinism contract for speed (the SIMD arm is still
    /// run-to-run deterministic at a fixed width). Inert unless the crate
    /// is built with `--features simd`.
    pub fn simd(&self) -> bool {
        self.bool_or("exec.simd", default_simd())
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str, lineno: usize) -> Result<Value, String> {
    if v.starts_with('"') && v.ends_with('"') && v.len() >= 2 {
        return Ok(Value::Str(v[1..v.len() - 1].to_string()));
    }
    if v == "true" {
        return Ok(Value::Bool(true));
    }
    if v == "false" {
        return Ok(Value::Bool(false));
    }
    if v.starts_with('[') && v.ends_with(']') {
        let inner = &v[1..v.len() - 1];
        let mut arr = Vec::new();
        for part in inner.split(',') {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            arr.push(
                p.parse::<f64>()
                    .map_err(|_| format!("line {lineno}: bad number '{p}'"))?,
            );
        }
        return Ok(Value::Array(arr));
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("line {lineno}: cannot parse value '{v}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let text = r#"
# experiment config
name = "ou"
epochs = 250
lr = 1e-3
stiff = false

[solver]
scheme = "ees25"   # the good one
step = 0.25
obs = [4, 8, 12]
"#;
        let c = Config::parse(text).unwrap();
        assert_eq!(c.str_or("name", ""), "ou");
        assert_eq!(c.usize_or("epochs", 0), 250);
        assert!((c.f64_or("lr", 0.0) - 1e-3).abs() < 1e-15);
        assert!(!c.bool_or("stiff", true));
        assert_eq!(c.str_or("solver.scheme", ""), "ees25");
        assert!((c.f64_or("solver.step", 0.0) - 0.25).abs() < 1e-15);
        match c.get("solver.obs").unwrap() {
            Value::Array(a) => assert_eq!(a, &vec![4.0, 8.0, 12.0]),
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let c = Config::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(c.str_or("tag", ""), "a#b");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("nonsense without equals").is_err());
        assert!(Config::parse("x = @@").is_err());
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("missing", 7), 7);
    }

    #[test]
    fn parallelism_knob() {
        let c = Config::parse("[exec]\nparallelism = 3").unwrap();
        assert_eq!(c.parallelism(), 3);
        let d = Config::parse("").unwrap();
        assert_eq!(d.parallelism(), default_parallelism());
        assert!(default_parallelism() >= 1);
    }

    #[test]
    fn lanes_knob() {
        let c = Config::parse("[exec]\nlanes = 4").unwrap();
        assert_eq!(c.lanes(), 4);
        // Clamped to the kernel cap and to >= 1.
        let big = Config::parse("[exec]\nlanes = 99").unwrap();
        assert_eq!(big.lanes(), crate::linalg::MAX_LANES);
        let zero = Config::parse("[exec]\nlanes = 0").unwrap();
        assert_eq!(zero.lanes(), 1);
        let d = Config::parse("").unwrap();
        assert_eq!(d.lanes(), default_lanes());
        assert!((1..=crate::linalg::MAX_LANES).contains(&default_lanes()));
    }

    #[test]
    fn simd_knob() {
        let on = Config::parse("[exec]\nsimd = true").unwrap();
        assert!(on.simd());
        let off = Config::parse("[exec]\nsimd = false").unwrap();
        assert!(!off.simd());
        let d = Config::parse("").unwrap();
        assert_eq!(d.simd(), default_simd());
    }
}
