//! Truncated path signatures and signature-kernel MMD losses.
//!
//! The paper trains its stochastic-volatility models with a "truncated
//! (time-augmented) path-signature MMD²" objective (Appendix I.4) and its
//! rough-Bergomi model with a signature-kernel score. We implement the
//! truncated signature of a piecewise-linear path up to a chosen depth via
//! Chen's relation, the induced linear-kernel MMD², and its gradient with
//! respect to the path values (needed to backpropagate into the NSDE
//! trajectory).

/// Number of signature coefficients of depth ≤ `depth` in dimension `d`
/// (excluding the constant 1): d + d² + … + d^depth.
pub fn sig_len(d: usize, depth: usize) -> usize {
    let mut total = 0usize;
    let mut p = 1usize;
    for _ in 0..depth {
        p *= d;
        total += p;
    }
    total
}

/// Truncated signature of a piecewise-linear path.
///
/// `path` is `(n_points, d)` flattened row-major. Returns coefficients of
/// words of length 1..=depth, grouped by level: [level1 (d), level2 (d²), …].
/// Computed by iterating Chen's identity with the closed-form signature of a
/// straight-line segment, exp(Δ) (tensor exponential of the increment).
pub fn signature(path: &[f64], n: usize, d: usize, depth: usize) -> Vec<f64> {
    assert!(n >= 1);
    let len = sig_len(d, depth);
    // sig levels: level k has d^k entries.
    let mut sig = vec![0.0; len];
    let mut seg = vec![0.0; len];
    let mut tmp = vec![0.0; len];
    let mut delta = vec![0.0; d];
    let level_off: Vec<usize> = {
        let mut offs = vec![0usize];
        let mut p = 1usize;
        for _ in 0..depth {
            p *= d;
            offs.push(offs.last().unwrap() + p);
        }
        offs
    };
    let mut first = true;
    for seg_i in 0..n - 1 {
        for k in 0..d {
            delta[k] = path[(seg_i + 1) * d + k] - path[seg_i * d + k];
        }
        // seg = exp⊗(delta): level k = delta^{⊗k}/k!.
        seg[..d].copy_from_slice(&delta);
        for lvl in 2..=depth {
            let (prev_lo, prev_hi) = (level_off[lvl - 2], level_off[lvl - 1]);
            let cur_lo = level_off[lvl - 1];
            let prev_len = prev_hi - prev_lo;
            let inv = 1.0 / lvl as f64;
            // split borrow: prev block comes before cur block
            let (head, tail) = seg.split_at_mut(cur_lo);
            let prev = &head[prev_lo..prev_hi];
            for i in 0..prev_len {
                for k in 0..d {
                    tail[i * d + k] = prev[i] * delta[k] * inv;
                }
            }
        }
        if first {
            sig.copy_from_slice(&seg);
            first = false;
            continue;
        }
        // Chen: sig ← sig ⊗ seg (truncated), with implicit unit terms.
        tmp.copy_from_slice(&sig);
        for (t, s) in tmp.iter_mut().zip(seg.iter()) {
            *t += s; // unit ⊗ seg and sig ⊗ unit contributions
        }
        for lvl in 2..=depth {
            // cross terms: level lvl += Σ_{a+b=lvl, a,b>=1} sig_a ⊗ seg_b
            let cur_lo = level_off[lvl - 1];
            for a in 1..lvl {
                let b = lvl - a;
                let (a_lo, a_hi) = (level_off[a - 1], level_off[a]);
                let (b_lo, b_hi) = (level_off[b - 1], level_off[b]);
                let b_len = b_hi - b_lo;
                for ia in 0..(a_hi - a_lo) {
                    let sa = sig[a_lo + ia];
                    if sa == 0.0 {
                        continue;
                    }
                    let base = cur_lo + ia * b_len;
                    for ib in 0..b_len {
                        tmp[base + ib] += sa * seg[b_lo + ib];
                    }
                }
            }
        }
        sig.copy_from_slice(&tmp);
    }
    sig
}

/// Time-augmented signature: prepends the (scaled) time channel so that the
/// signature separates paths up to reparametrisation.
pub fn signature_time_augmented(
    values: &[f64],
    n: usize,
    d: usize,
    dt: f64,
    depth: usize,
) -> Vec<f64> {
    let mut aug = vec![0.0; n * (d + 1)];
    for i in 0..n {
        aug[i * (d + 1)] = i as f64 * dt;
        aug[i * (d + 1) + 1..(i + 1) * (d + 1)].copy_from_slice(&values[i * d..(i + 1) * d]);
    }
    signature(&aug, n, d + 1, depth)
}

/// Unbiased linear-kernel MMD² between two samples of signature features:
/// MMD² = ‖mean(X) − mean(Y)‖² with the unbiased within-sample corrections.
pub fn mmd2_linear(xs: &[Vec<f64>], ys: &[Vec<f64>]) -> f64 {
    let (m, n) = (xs.len(), ys.len());
    assert!(m >= 2 && n >= 2);
    let dim = xs[0].len();
    let dot = |a: &[f64], b: &[f64]| a.iter().zip(b.iter()).map(|(x, y)| x * y).sum::<f64>();
    let mut mean_x = vec![0.0; dim];
    let mut mean_y = vec![0.0; dim];
    for x in xs {
        for (mi, xi) in mean_x.iter_mut().zip(x.iter()) {
            *mi += xi;
        }
    }
    for y in ys {
        for (mi, yi) in mean_y.iter_mut().zip(y.iter()) {
            *mi += yi;
        }
    }
    // Unbiased estimates: E k(x,x') over distinct pairs.
    let sum_xx: f64 = dot(&mean_x, &mean_x) - xs.iter().map(|x| dot(x, x)).sum::<f64>();
    let sum_yy: f64 = dot(&mean_y, &mean_y) - ys.iter().map(|y| dot(y, y)).sum::<f64>();
    let sum_xy: f64 = dot(&mean_x, &mean_y);
    sum_xx / (m * (m - 1)) as f64 + sum_yy / (n * (n - 1)) as f64
        - 2.0 * sum_xy / (m * n) as f64
}

/// Biased linear-kernel MMD²: ‖mean φ(X) − mean φ(Y)‖² (zero for identical
/// samples; the differentiable objective used during training).
pub fn mmd2_linear_biased(xs: &[Vec<f64>], ys: &[Vec<f64>]) -> f64 {
    let dim = xs[0].len();
    let (m, n) = (xs.len() as f64, ys.len() as f64);
    let mut diff = vec![0.0; dim];
    for x in xs {
        for (d, xi) in diff.iter_mut().zip(x.iter()) {
            *d += xi / m;
        }
    }
    for y in ys {
        for (d, yi) in diff.iter_mut().zip(y.iter()) {
            *d -= yi / n;
        }
    }
    diff.iter().map(|d| d * d).sum()
}

/// Gradient of the *biased* linear MMD² (‖mean φ(X) − mean φ(Y)‖²) with
/// respect to each x-feature vector: 2(mean φ(X) − mean φ(Y))/m. Returned as
/// a single vector to be applied to every generated sample's feature
/// cotangent (the feature Jacobian is handled by the caller through the
/// signature VJP or finite differences).
pub fn mmd2_feature_cotangent(xs: &[Vec<f64>], ys: &[Vec<f64>]) -> Vec<f64> {
    let dim = xs[0].len();
    let m = xs.len() as f64;
    let n = ys.len() as f64;
    let mut g = vec![0.0; dim];
    for x in xs {
        for (gi, xi) in g.iter_mut().zip(x.iter()) {
            *gi += xi / m;
        }
    }
    for y in ys {
        for (gi, yi) in g.iter_mut().zip(y.iter()) {
            *gi -= yi / n;
        }
    }
    for gi in g.iter_mut() {
        *gi *= 2.0 / m;
    }
    g
}

/// VJP of [`signature`] with respect to the path values, by forward-mode
/// finite differences batched over path entries (paths here are short — the
/// loss-bearing coarse grid — so n·d extra signatures are affordable).
pub fn signature_vjp_fd(
    path: &[f64],
    n: usize,
    d: usize,
    depth: usize,
    cot: &[f64],
) -> Vec<f64> {
    let mut grad = vec![0.0; n * d];
    let eps = 1e-6;
    let mut p = path.to_vec();
    for k in 0..n * d {
        let orig = p[k];
        p[k] = orig + eps;
        let sp = signature(&p, n, d, depth);
        p[k] = orig - eps;
        let sm = signature(&p, n, d, depth);
        p[k] = orig;
        let mut acc = 0.0;
        for (i, c) in cot.iter().enumerate() {
            acc += c * (sp[i] - sm[i]) / (2.0 * eps);
        }
        grad[k] = acc;
    }
    grad
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sig_len_counts() {
        assert_eq!(sig_len(2, 3), 2 + 4 + 8);
        assert_eq!(sig_len(3, 2), 3 + 9);
    }

    /// Signature of a straight line is exp(Δ): level k = Δ^{⊗k}/k!.
    #[test]
    fn straight_line_signature() {
        let path = [0.0, 0.0, 1.0, 2.0]; // 2 points in R²
        let s = signature(&path, 2, 2, 3);
        assert!((s[0] - 1.0).abs() < 1e-14);
        assert!((s[1] - 2.0).abs() < 1e-14);
        // Level 2: (1/2)·[1,2]⊗[1,2] = [0.5, 1, 1, 2].
        assert!((s[2] - 0.5).abs() < 1e-14);
        assert!((s[3] - 1.0).abs() < 1e-14);
        assert!((s[4] - 1.0).abs() < 1e-14);
        assert!((s[5] - 2.0).abs() < 1e-14);
        // Level 3: (1/6)Δ⊗Δ⊗Δ; entry (1,1,1) = 1/6.
        assert!((s[6] - 1.0 / 6.0).abs() < 1e-14);
    }

    /// Chen's identity: signature of concatenation = tensor product.
    /// Check via the shuffle-free scalar identity: level-1 adds, and the
    /// (1,2)+(2,1) antisymmetric part equals the Lévy area.
    #[test]
    fn chen_level1_additivity_and_levy_area() {
        let path = [0.0, 0.0, 1.0, 0.0, 1.0, 1.0]; // L-shaped path
        let s = signature(&path, 3, 2, 2);
        assert!((s[0] - 1.0).abs() < 1e-14);
        assert!((s[1] - 1.0).abs() < 1e-14);
        // S^{12} = ∫ dx1 dx2 over x1 then x2 = 1·1 = 1; S^{21} = 0.
        assert!((s[3] - 1.0).abs() < 1e-14, "S12 {}", s[3]);
        assert!((s[4] - 0.0).abs() < 1e-14, "S21 {}", s[4]);
        // Symmetric parts: S11 = 1/2, S22 = 1/2.
        assert!((s[2] - 0.5).abs() < 1e-14);
        assert!((s[5] - 0.5).abs() < 1e-14);
    }

    /// Signature is invariant under adding a collinear midpoint.
    #[test]
    fn reparametrisation_invariance() {
        let p1 = [0.0, 0.0, 2.0, 4.0];
        let p2 = [0.0, 0.0, 1.0, 2.0, 2.0, 4.0];
        let s1 = signature(&p1, 2, 2, 4);
        let s2 = signature(&p2, 3, 2, 4);
        for (a, b) in s1.iter().zip(s2.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn biased_mmd_zero_for_identical_samples() {
        let xs: Vec<Vec<f64>> = (0..4).map(|i| vec![i as f64, 1.0]).collect();
        let m = mmd2_linear_biased(&xs, &xs);
        assert!(m.abs() < 1e-12, "{m}");
    }

    /// The unbiased estimator is ≈0 in expectation for equal distributions.
    #[test]
    fn unbiased_mmd_near_zero_same_distribution() {
        let mut rng = crate::rng::Pcg64::new(2);
        let mut acc = 0.0;
        let reps = 200;
        for _ in 0..reps {
            let xs: Vec<Vec<f64>> = (0..16).map(|_| vec![rng.normal(), rng.normal()]).collect();
            let ys: Vec<Vec<f64>> = (0..16).map(|_| vec![rng.normal(), rng.normal()]).collect();
            acc += mmd2_linear(&xs, &ys);
        }
        let mean = acc / reps as f64;
        assert!(mean.abs() < 0.05, "unbiased MMD mean {mean}");
    }

    #[test]
    fn mmd_positive_for_shifted_samples() {
        let xs: Vec<Vec<f64>> = (0..8).map(|i| vec![(i % 3) as f64 * 0.1]).collect();
        let ys: Vec<Vec<f64>> = (0..8).map(|i| vec![(i % 3) as f64 * 0.1 + 5.0]).collect();
        assert!(mmd2_linear(&xs, &ys) > 1.0);
    }

    #[test]
    fn signature_vjp_matches_loss_fd() {
        // d/dpath of <cot, sig(path)> via our FD helper vs direct FD of the
        // scalar — sanity of indexing.
        let path = [0.0, 0.0, 0.5, 1.0, 1.5, 0.5];
        let depth = 2;
        let s = signature(&path, 3, 2, depth);
        let cot: Vec<f64> = (0..s.len()).map(|i| (i as f64 * 0.37).sin()).collect();
        let g = signature_vjp_fd(&path, 3, 2, depth, &cot);
        let f = |p: &[f64]| -> f64 {
            signature(p, 3, 2, depth)
                .iter()
                .zip(cot.iter())
                .map(|(a, b)| a * b)
                .sum()
        };
        let eps = 1e-5;
        for k in 0..6 {
            let mut pp = path;
            pp[k] += eps;
            let mut pm = path;
            pm[k] -= eps;
            let fd = (f(&pp) - f(&pm)) / (2.0 * eps);
            assert!((fd - g[k]).abs() < 1e-6, "{k}: {fd} vs {}", g[k]);
        }
    }

    #[test]
    fn time_augmentation_separates_speed() {
        // Same geometric image traversed at different speeds must differ
        // once time-augmented.
        let v1 = [0.0, 1.0, 2.0]; // linear
        let v2 = [0.0, 1.9, 2.0]; // fast then slow
        let s1 = signature_time_augmented(&v1, 3, 1, 0.5, 2);
        let s2 = signature_time_augmented(&v2, 3, 1, 0.5, 2);
        let diff: f64 = s1
            .iter()
            .zip(s2.iter())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 0.1, "time augmentation failed to separate: {diff}");
    }
}
