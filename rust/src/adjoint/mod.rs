//! Adjoint methods: Full (discretise-then-optimise), Recursive
//! (checkpointing, O(√n) memory) and Reversible (Algorithm 1/2, O(1)
//! memory) — the three columns the paper compares throughout Section 4.
//!
//! Losses observe the trajectory at a set of observation indices; the
//! backward sweep injects the per-observation cotangents as it walks the
//! steps in reverse. Where the state at a step start comes from is the only
//! difference between the methods:
//!
//! - **Full**: read from a tape of every solver state (O(n));
//! - **Recursive**: recompute each √n-sized segment from its checkpoint
//!   (O(√n) storage, one extra forward pass);
//! - **Reversible**: reconstruct by the solver's algebraic inverse
//!   `step_back` (O(1); exact for Reversible Heun/MCF, order-m for EES).
//!
//! All storage passes through [`crate::memory::MemMeter`], so the paper's
//! memory curves are measured, not asserted.

use crate::lie::HomogeneousSpace;
use crate::memory::{MemMeter, MeteredTape, StepWorkspace};
use crate::rng::{BrownianPath, BrownianSource};
use crate::solvers::{ManifoldStepper, Stepper};
use crate::vf::{DiffManifoldVectorField, DiffVectorField};

/// Per-step driver increments for a uniform grid, either borrowed from a
/// pre-sampled [`BrownianPath`] or queried on the fly from a
/// [`BrownianSource`] — the latter is what lets the reversible adjoint walk
/// the steps backwards with O(1) noise memory (the tree is queried per
/// step; no `reversed()` path is ever materialised).
enum StepNoise<'a> {
    /// Increments read straight from a sampled grid path.
    Grid(&'a BrownianPath),
    /// Increments queried from a source over [t0 + n·h, t0 + (n+1)·h].
    Source {
        src: &'a dyn BrownianSource,
        t0: f64,
        h: f64,
        buf: Vec<f64>,
    },
}

impl StepNoise<'_> {
    /// Driver increment of step `n` (forward or backward sweeps query the
    /// same interval — consistency is the source's contract).
    fn inc(&mut self, n: usize, ws: &mut StepWorkspace) -> &[f64] {
        match self {
            StepNoise::Grid(p) => p.increment(n),
            StepNoise::Source { src, t0, h, buf } => {
                let a = *t0 + n as f64 * *h;
                src.increment_ws(a, a + *h, buf, ws);
                buf
            }
        }
    }
}

/// Which adjoint realisation to use for the backward pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdjointMethod {
    /// Discretise-then-optimise with a full tape: O(n) memory.
    Full,
    /// √n checkpointing with per-segment recomputation: O(√n) memory.
    Recursive,
    /// Algebraic reconstruction by `step_back` (Algorithm 1/2): O(1) memory.
    Reversible,
}

impl AdjointMethod {
    /// Human-readable name as used in the paper's table columns.
    pub fn name(&self) -> &'static str {
        match self {
            AdjointMethod::Full => "Full",
            AdjointMethod::Recursive => "Recursive",
            AdjointMethod::Reversible => "Reversible",
        }
    }
}

/// Loss over observed states. `obs_states` is `(n_obs, dim)` flattened in
/// observation order.
pub trait ObservationLoss: Send + Sync {
    /// Loss value at the observed states.
    fn eval(&self, obs_states: &[f64], dim: usize) -> f64;
    /// Cotangents dL/d(obs state), same layout as `obs_states`.
    fn grad(&self, obs_states: &[f64], dim: usize) -> Vec<f64>;
}

/// Squared distance to per-observation targets: Σ ‖y_obs − target‖² / n_obs.
pub struct MseToTargets {
    /// Flattened `(n_obs, dim)` targets.
    pub targets: Vec<f64>,
}

impl ObservationLoss for MseToTargets {
    fn eval(&self, obs_states: &[f64], _dim: usize) -> f64 {
        let n = self.targets.len();
        obs_states
            .iter()
            .zip(self.targets.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / n as f64
    }
    fn grad(&self, obs_states: &[f64], _dim: usize) -> Vec<f64> {
        let n = self.targets.len();
        obs_states
            .iter()
            .zip(self.targets.iter())
            .map(|(a, b)| 2.0 * (a - b) / n as f64)
            .collect()
    }
}

/// Result of one forward+backward solve.
#[derive(Clone, Debug)]
pub struct GradResult {
    /// Loss value at the observed states.
    pub loss: f64,
    /// Cotangent with respect to the full initial solver state
    /// (primary y₀ in the first `dim` slots).
    pub d_state0: Vec<f64>,
    /// Parameter gradient (flat θ layout of the vector field).
    pub d_theta: Vec<f64>,
    /// Peak adjoint-machinery memory (f64 slots).
    pub peak_f64s: usize,
}

/// Forward + backward through a Euclidean SDE solve.
///
/// `obs` must be sorted ascending step indices in 1..=steps (observation
/// after that many steps). The loss sees the primary states at those
/// indices.
pub fn grad_euclidean(
    stepper: &dyn Stepper,
    method: AdjointMethod,
    vf: &dyn DiffVectorField,
    t0: f64,
    y0: &[f64],
    path: &BrownianPath,
    obs: &[usize],
    loss: &dyn ObservationLoss,
) -> GradResult {
    let mut noise = StepNoise::Grid(path);
    grad_euclidean_noise(
        stepper,
        method,
        vf,
        t0,
        y0,
        path.h,
        path.steps(),
        &mut noise,
        obs,
        loss,
    )
}

/// [`grad_euclidean`] over a query-anywhere noise source: a uniform grid of
/// `steps` steps spanning [source.t0(), source.t1()], with every increment
/// — forward *and* backward — queried from the source on the fly. With a
/// [`crate::rng::VirtualBrownianTree`] the whole forward+backward solve
/// under the Reversible method holds O(1) state *and* O(1) noise: nothing
/// grid-shaped is ever materialised.
pub fn grad_euclidean_source(
    stepper: &dyn Stepper,
    method: AdjointMethod,
    vf: &dyn DiffVectorField,
    y0: &[f64],
    source: &dyn BrownianSource,
    steps: usize,
    obs: &[usize],
    loss: &dyn ObservationLoss,
) -> GradResult {
    let t0 = source.t0();
    let h = (source.t1() - t0) / steps as f64;
    let mut noise = StepNoise::Source {
        src: source,
        t0,
        h,
        buf: vec![0.0; vf.noise_dim()],
    };
    grad_euclidean_noise(stepper, method, vf, t0, y0, h, steps, &mut noise, obs, loss)
}

/// Shared forward+backward sweep behind [`grad_euclidean`] and
/// [`grad_euclidean_source`].
fn grad_euclidean_noise(
    stepper: &dyn Stepper,
    method: AdjointMethod,
    vf: &dyn DiffVectorField,
    t0: f64,
    y0: &[f64],
    h: f64,
    steps: usize,
    noise: &mut StepNoise<'_>,
    obs: &[usize],
    loss: &dyn ObservationLoss,
) -> GradResult {
    let dim = vf.dim();
    let state_size = stepper.state_size(dim);
    let mut meter = MemMeter::new();
    // Constant-cost registers: current state + cotangent.
    meter.alloc(2 * state_size);

    let seg = if method == AdjointMethod::Recursive {
        (steps as f64).sqrt().ceil() as usize
    } else {
        0
    };

    let mut state = stepper.init_state(vf, t0, y0);
    let mut tape = MeteredTape::new(); // Full: every state; Recursive: checkpoints.
    let mut obs_states = vec![0.0; obs.len() * dim];
    // One scratch arena serves the whole forward+reverse trajectory: after
    // the first step warms it, the sweep performs zero heap allocations.
    let mut ws = StepWorkspace::new();

    // ---- forward ----
    let mut obs_i = 0;
    if method == AdjointMethod::Full || method == AdjointMethod::Recursive {
        tape.push(&state, &mut meter); // state at step 0
    }
    for n in 0..steps {
        let t = t0 + n as f64 * h;
        let dw = noise.inc(n, &mut ws);
        stepper.step_ws(vf, t, h, dw, &mut state, &mut ws);
        match method {
            AdjointMethod::Full => tape.push(&state, &mut meter),
            AdjointMethod::Recursive => {
                if (n + 1) % seg == 0 {
                    tape.push(&state, &mut meter);
                }
            }
            AdjointMethod::Reversible => {}
        }
        while obs_i < obs.len() && obs[obs_i] == n + 1 {
            obs_states[obs_i * dim..(obs_i + 1) * dim].copy_from_slice(&state[..dim]);
            obs_i += 1;
        }
    }
    debug_assert_eq!(obs_i, obs.len(), "observation indices must be in 1..=steps");

    let loss_val = loss.eval(&obs_states, dim);
    let cots = loss.grad(&obs_states, dim);

    // ---- backward ----
    let mut lambda = vec![0.0; state_size];
    let mut d_theta = vec![0.0; vf.num_params()];
    meter.alloc(d_theta.len());
    let mut obs_i = obs.len();
    // Recursive: segment buffer of recomputed states.
    let mut seg_buf = MeteredTape::new();
    for n in (0..steps).rev() {
        while obs_i > 0 && obs[obs_i - 1] == n + 1 {
            obs_i -= 1;
            for d in 0..dim {
                lambda[d] += cots[obs_i * dim + d];
            }
        }
        let t = t0 + n as f64 * h;
        match method {
            AdjointMethod::Full => {
                let dw = noise.inc(n, &mut ws);
                stepper.backprop_step_ws(
                    vf, t, h, dw, tape.get(n), &mut lambda, &mut d_theta, &mut ws,
                );
            }
            AdjointMethod::Reversible => {
                // The backward sweep re-queries the source per step (for a
                // virtual tree: no reversed path is ever materialised).
                let dw = noise.inc(n, &mut ws);
                stepper.step_back_ws(vf, t, h, dw, &mut state, &mut ws);
                stepper.backprop_step_ws(vf, t, h, dw, &state, &mut lambda, &mut d_theta, &mut ws);
            }
            AdjointMethod::Recursive => {
                if seg_buf.is_empty() {
                    // Recompute states for the segment containing step n
                    // from the checkpoint at segment start.
                    let seg_start = (n / seg) * seg;
                    let ckpt_idx = n / seg;
                    let mut s = tape.get(ckpt_idx).to_vec();
                    seg_buf.push(&s, &mut meter);
                    for m in seg_start..n {
                        let tm = t0 + m as f64 * h;
                        let dwm = noise.inc(m, &mut ws);
                        stepper.step_ws(vf, tm, h, dwm, &mut s, &mut ws);
                        seg_buf.push(&s, &mut meter);
                    }
                }
                let prev = seg_buf.pop(&mut meter).expect("segment buffer underflow");
                let dw = noise.inc(n, &mut ws);
                stepper.backprop_step_ws(vf, t, h, dw, &prev, &mut lambda, &mut d_theta, &mut ws);
            }
        }
    }
    while obs_i > 0 && obs[obs_i - 1] == 0 {
        obs_i -= 1;
        for d in 0..dim {
            lambda[d] += cots[obs_i * dim + d];
        }
    }
    GradResult {
        loss: loss_val,
        d_state0: lambda,
        d_theta,
        peak_f64s: meter.peak_f64s(),
    }
}

/// Forward + backward through a homogeneous-space SDE solve (Algorithm 2).
pub fn grad_manifold(
    stepper: &dyn ManifoldStepper,
    method: AdjointMethod,
    sp: &dyn HomogeneousSpace,
    vf: &dyn DiffManifoldVectorField,
    t0: f64,
    y0: &[f64],
    path: &BrownianPath,
    obs: &[usize],
    loss: &dyn ObservationLoss,
) -> GradResult {
    let mut noise = StepNoise::Grid(path);
    grad_manifold_noise(
        stepper,
        method,
        sp,
        vf,
        t0,
        y0,
        path.h,
        path.steps(),
        &mut noise,
        obs,
        loss,
    )
}

/// [`grad_manifold`] over a query-anywhere noise source (see
/// [`grad_euclidean_source`] for the grid convention and the O(1)-noise
/// property of the Reversible method).
pub fn grad_manifold_source(
    stepper: &dyn ManifoldStepper,
    method: AdjointMethod,
    sp: &dyn HomogeneousSpace,
    vf: &dyn DiffManifoldVectorField,
    y0: &[f64],
    source: &dyn BrownianSource,
    steps: usize,
    obs: &[usize],
    loss: &dyn ObservationLoss,
) -> GradResult {
    let t0 = source.t0();
    let h = (source.t1() - t0) / steps as f64;
    let mut noise = StepNoise::Source {
        src: source,
        t0,
        h,
        buf: vec![0.0; vf.noise_dim()],
    };
    grad_manifold_noise(
        stepper, method, sp, vf, t0, y0, h, steps, &mut noise, obs, loss,
    )
}

/// Shared forward+backward sweep behind [`grad_manifold`] and
/// [`grad_manifold_source`].
fn grad_manifold_noise(
    stepper: &dyn ManifoldStepper,
    method: AdjointMethod,
    sp: &dyn HomogeneousSpace,
    vf: &dyn DiffManifoldVectorField,
    t0: f64,
    y0: &[f64],
    h: f64,
    steps: usize,
    noise: &mut StepNoise<'_>,
    obs: &[usize],
    loss: &dyn ObservationLoss,
) -> GradResult {
    let dim = sp.point_dim();
    let mut meter = MemMeter::new();
    // Constant registers: state, cotangent, δ register, stage scratch.
    meter.alloc(2 * dim + 2 * sp.algebra_dim());

    let seg = if method == AdjointMethod::Recursive {
        (steps as f64).sqrt().ceil() as usize
    } else {
        0
    };
    if method == AdjointMethod::Reversible {
        assert!(
            stepper.reversible(),
            "{} does not support the reversible adjoint",
            stepper.name()
        );
    }

    let mut y = y0.to_vec();
    let mut tape = MeteredTape::new();
    let mut obs_states = vec![0.0; obs.len() * dim];
    let mut ws = StepWorkspace::new();
    let mut obs_i = 0;
    if method != AdjointMethod::Reversible {
        tape.push(&y, &mut meter);
    }
    for n in 0..steps {
        let t = t0 + n as f64 * h;
        let dw = noise.inc(n, &mut ws);
        stepper.step_ws(sp, vf, t, h, dw, &mut y, &mut ws);
        match method {
            AdjointMethod::Full => tape.push(&y, &mut meter),
            AdjointMethod::Recursive => {
                if (n + 1) % seg == 0 {
                    tape.push(&y, &mut meter);
                }
            }
            AdjointMethod::Reversible => {}
        }
        while obs_i < obs.len() && obs[obs_i] == n + 1 {
            obs_states[obs_i * dim..(obs_i + 1) * dim].copy_from_slice(&y);
            obs_i += 1;
        }
    }
    let loss_val = loss.eval(&obs_states, dim);
    let cots = loss.grad(&obs_states, dim);

    let mut lambda = vec![0.0; dim];
    let mut d_theta = vec![0.0; vf.num_params()];
    meter.alloc(d_theta.len());
    let mut obs_i = obs.len();
    let mut seg_buf = MeteredTape::new();
    for n in (0..steps).rev() {
        while obs_i > 0 && obs[obs_i - 1] == n + 1 {
            obs_i -= 1;
            for d in 0..dim {
                lambda[d] += cots[obs_i * dim + d];
            }
        }
        let t = t0 + n as f64 * h;
        match method {
            AdjointMethod::Full => {
                let dw = noise.inc(n, &mut ws);
                stepper.backprop_step_ws(
                    sp, vf, t, h, dw, tape.get(n), &mut lambda, &mut d_theta, &mut ws,
                );
            }
            AdjointMethod::Reversible => {
                let dw = noise.inc(n, &mut ws);
                stepper.step_back_ws(sp, vf, t, h, dw, &mut y, &mut ws);
                stepper.backprop_step_ws(
                    sp, vf, t, h, dw, &y, &mut lambda, &mut d_theta, &mut ws,
                );
            }
            AdjointMethod::Recursive => {
                if seg_buf.is_empty() {
                    let seg_start = (n / seg) * seg;
                    let ckpt_idx = n / seg;
                    let mut s = tape.get(ckpt_idx).to_vec();
                    seg_buf.push(&s, &mut meter);
                    for m in seg_start..n {
                        let tm = t0 + m as f64 * h;
                        let dwm = noise.inc(m, &mut ws);
                        stepper.step_ws(sp, vf, tm, h, dwm, &mut s, &mut ws);
                        seg_buf.push(&s, &mut meter);
                    }
                }
                let prev = seg_buf.pop(&mut meter).expect("segment buffer underflow");
                let dw = noise.inc(n, &mut ws);
                stepper.backprop_step_ws(
                    sp, vf, t, h, dw, &prev, &mut lambda, &mut d_theta, &mut ws,
                );
            }
        }
    }
    while obs_i > 0 && obs[obs_i - 1] == 0 {
        obs_i -= 1;
        for d in 0..dim {
            lambda[d] += cots[obs_i * dim + d];
        }
    }
    GradResult {
        loss: loss_val,
        d_state0: lambda,
        d_theta,
        peak_f64s: meter.peak_f64s(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::solvers::{LowStorageStepper, Mcf, ReversibleHeun, RkStepper};
    use crate::vf::VectorField;

    /// Tiny parametric field for exactness checks.
    struct PF {
        theta: Vec<f64>,
    }
    impl VectorField for PF {
        fn dim(&self) -> usize {
            2
        }
        fn noise_dim(&self) -> usize {
            1
        }
        fn combined(&self, _t: f64, y: &[f64], h: f64, dw: &[f64], out: &mut [f64]) {
            out[0] = (self.theta[0] * y[1] - y[0]) * h + self.theta[2] * dw[0];
            out[1] = (self.theta[1] * y[0].tanh()) * h + 0.2 * y[1] * dw[0];
        }
    }
    impl DiffVectorField for PF {
        fn num_params(&self) -> usize {
            3
        }
        fn vjp(
            &self,
            _t: f64,
            y: &[f64],
            h: f64,
            dw: &[f64],
            cot: &[f64],
            d_y: &mut [f64],
            d_theta: &mut [f64],
        ) {
            d_y[0] += -cot[0] * h + cot[1] * self.theta[1] * (1.0 - y[0].tanh().powi(2)) * h;
            d_y[1] += cot[0] * self.theta[0] * h + cot[1] * 0.2 * dw[0];
            d_theta[0] += cot[0] * y[1] * h;
            d_theta[1] += cot[1] * y[0].tanh() * h;
            d_theta[2] += cot[0] * dw[0];
        }
    }

    fn setup() -> (PF, BrownianPath, Vec<usize>, MseToTargets) {
        let vf = PF {
            theta: vec![0.6, -0.9, 0.3],
        };
        let mut rng = Pcg64::new(42);
        let path = BrownianPath::sample(&mut rng, 1, 64, 1.0 / 64.0);
        let obs: Vec<usize> = vec![16, 32, 48, 64];
        let targets = vec![0.1; 4 * 2];
        (vf, path, obs, MseToTargets { targets })
    }

    /// Table 12 in miniature: the three adjoints return the same gradient
    /// (up to the EES reconstruction defect, which is ~1e-9 here).
    #[test]
    fn adjoints_agree_euclidean() {
        let (vf, path, obs, loss) = setup();
        let st = LowStorageStepper::ees25();
        let y0 = [0.4, -0.2];
        let g_full = grad_euclidean(
            &st,
            AdjointMethod::Full,
            &vf,
            0.0,
            &y0,
            &path,
            &obs,
            &loss,
        );
        for m in [AdjointMethod::Recursive, AdjointMethod::Reversible] {
            let g = grad_euclidean(&st, m, &vf, 0.0, &y0, &path, &obs, &loss);
            assert!((g.loss - g_full.loss).abs() < 1e-9);
            for (a, b) in g.d_theta.iter().zip(g_full.d_theta.iter()) {
                assert!((a - b).abs() < 1e-7, "{}: {a} vs {b}", m.name());
            }
            for (a, b) in g.d_state0.iter().zip(g_full.d_state0.iter()) {
                assert!((a - b).abs() < 1e-7, "{}: {a} vs {b}", m.name());
            }
        }
    }

    /// Full-adjoint gradient matches finite differences end-to-end.
    #[test]
    fn full_adjoint_matches_fd() {
        let (vf, path, obs, loss) = setup();
        let st = RkStepper::ees25();
        let y0 = [0.4, -0.2];
        let g = grad_euclidean(
            &st,
            AdjointMethod::Full,
            &vf,
            0.0,
            &y0,
            &path,
            &obs,
            &loss,
        );
        let run_loss = |theta: &[f64], y0: &[f64]| -> f64 {
            let vf = PF {
                theta: theta.to_vec(),
            };
            let traj = crate::solvers::integrate(&st, &vf, 0.0, y0, &path);
            let mut obs_states = vec![0.0; obs.len() * 2];
            for (i, &n) in obs.iter().enumerate() {
                obs_states[i * 2..(i + 1) * 2].copy_from_slice(&traj[n * 2..(n + 1) * 2]);
            }
            loss.eval(&obs_states, 2)
        };
        let eps = 1e-6;
        for k in 0..3 {
            let mut tp = vf.theta.clone();
            tp[k] += eps;
            let mut tm = vf.theta.clone();
            tm[k] -= eps;
            let fd = (run_loss(&tp, &y0) - run_loss(&tm, &y0)) / (2.0 * eps);
            assert!(
                (fd - g.d_theta[k]).abs() < 1e-6,
                "theta {k}: {fd} vs {}",
                g.d_theta[k]
            );
        }
        for k in 0..2 {
            let mut yp = y0;
            yp[k] += eps;
            let mut ym = y0;
            ym[k] -= eps;
            let fd = (run_loss(&vf.theta, &yp) - run_loss(&vf.theta, &ym)) / (2.0 * eps);
            assert!(
                (fd - g.d_state0[k]).abs() < 1e-6,
                "y0 {k}: {fd} vs {}",
                g.d_state0[k]
            );
        }
    }

    /// Reversible adjoint on exactly reversible schemes equals Full exactly.
    #[test]
    fn reversible_adjoint_exact_for_algebraic_schemes() {
        let (vf, path, obs, loss) = setup();
        for st in [
            Box::new(ReversibleHeun::new()) as Box<dyn Stepper>,
            Box::new(Mcf::euler()),
            Box::new(Mcf::midpoint()),
        ] {
            let y0 = [0.4, -0.2];
            let g_full = grad_euclidean(
                st.as_ref(),
                AdjointMethod::Full,
                &vf,
                0.0,
                &y0,
                &path,
                &obs,
                &loss,
            );
            let g_rev = grad_euclidean(
                st.as_ref(),
                AdjointMethod::Reversible,
                &vf,
                0.0,
                &y0,
                &path,
                &obs,
                &loss,
            );
            for (a, b) in g_rev.d_theta.iter().zip(g_full.d_theta.iter()) {
                assert!(
                    (a - b).abs() < 1e-10 * (1.0 + b.abs()),
                    "{}: {a} vs {b}",
                    st.props().name
                );
            }
        }
    }

    /// Memory complexity: Full grows linearly, Recursive ~√n, Reversible flat.
    #[test]
    fn memory_complexity_scaling() {
        let vf = PF {
            theta: vec![0.6, -0.9, 0.3],
        };
        let st = LowStorageStepper::ees25();
        let y0 = [0.4, -0.2];
        let mut rng = Pcg64::new(1);
        let peak = |method: AdjointMethod, steps: usize, rng: &mut Pcg64| -> usize {
            let path = BrownianPath::sample(rng, 1, steps, 1.0 / steps as f64);
            let obs = vec![steps];
            let loss = MseToTargets {
                targets: vec![0.0; 2],
            };
            grad_euclidean(&st, method, &vf, 0.0, &y0, &path, &obs, &loss).peak_f64s
        };
        let (f1, f4) = (
            peak(AdjointMethod::Full, 256, &mut rng),
            peak(AdjointMethod::Full, 1024, &mut rng),
        );
        assert!(
            (f4 as f64 / f1 as f64) > 3.0,
            "Full must scale ~linearly: {f1} -> {f4}"
        );
        let (r1, r4) = (
            peak(AdjointMethod::Recursive, 256, &mut rng),
            peak(AdjointMethod::Recursive, 1024, &mut rng),
        );
        let ratio = r4 as f64 / r1 as f64;
        assert!(
            ratio > 1.5 && ratio < 3.0,
            "Recursive must scale ~√n: {r1} -> {r4}"
        );
        let (v1, v4) = (
            peak(AdjointMethod::Reversible, 256, &mut rng),
            peak(AdjointMethod::Reversible, 1024, &mut rng),
        );
        assert_eq!(v1, v4, "Reversible must be O(1): {v1} -> {v4}");
        assert!(v4 < r4 && r4 < f4);
    }
}
