//! Minimal error type with context chaining — the in-crate stand-in for
//! `anyhow` (the offline build carries no external dependencies; see the
//! dependency policy note in `Cargo.toml`).
//!
//! The API mirrors the `anyhow` subset the crate uses:
//! [`Error`] (an opaque, message-carrying error), the [`Context`] extension
//! trait on `Result`, the crate-wide [`crate::Result`] alias, and the
//! [`format_err!`](crate::format_err) macro for ad-hoc errors.

use std::fmt;

/// Opaque error: a root cause plus a stack of human-readable context frames
/// (outermost first when displayed, like `anyhow`'s `{:#}` chain).
pub struct Error {
    /// Context frames in attachment order (innermost first); Display walks
    /// them in reverse so the outermost frame prints first.
    context: Vec<String>,
    /// Root cause. Either a boxed source error or a plain message.
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
    message: String,
}

impl Error {
    /// Create an error from a plain message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            context: Vec::new(),
            source: None,
            message: message.to_string(),
        }
    }

    /// Attach a context frame (what was being attempted when this failed).
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Self {
        self.context.push(ctx.to_string());
        self
    }

    /// The root-cause message (without context frames).
    pub fn root_cause(&self) -> &str {
        &self.message
    }
}

// NB: like `anyhow::Error`, this type deliberately does NOT implement
// `std::error::Error` — that is what allows the blanket `From` below without
// a conflicting reflexive impl.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self {
            context: Vec::new(),
            message: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for ctx in self.context.iter().rev() {
            write!(f, "{ctx}: ")?;
        }
        write!(f, "{}", self.message)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")?;
        if let Some(src) = &self.source {
            let mut cur: Option<&(dyn std::error::Error + 'static)> = src.source();
            while let Some(c) = cur {
                write!(f, "\ncaused by: {c}")?;
                cur = c.source();
            }
        }
        Ok(())
    }
}

/// Extension trait adding `anyhow`-style `.context(...)` /
/// `.with_context(...)` to any `Result` whose error converts into [`Error`].
pub trait Context<T> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (the in-crate `anyhow!`).
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String, std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Error = io_fail()
            .context("read config")
            .context("load experiment")
            .unwrap_err();
        assert_eq!(format!("{e}"), "load experiment: read config: gone");
    }

    #[test]
    fn option_context() {
        let n: Option<usize> = None;
        let e = n.context("missing value").unwrap_err();
        assert_eq!(e.root_cause(), "missing value");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<(), Error> {
            io_fail()?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn format_err_macro() {
        let e = format_err!("bad value {}", 7);
        assert_eq!(e.root_cause(), "bad value 7");
    }
}
