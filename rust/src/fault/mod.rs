//! Deterministic fault injection and the crash-safe write helpers built on
//! it — the chaos layer behind the supervised serve/risk/train recovery
//! paths (docs/ARCHITECTURE.md §Fault model & supervised recovery).
//!
//! # Why injection is deterministic
//!
//! The repo's recovery contract is *bitwise invisibility*: a run that
//! panics, retries, respawns a worker or resumes from a checkpoint must
//! reproduce the fault-free bytes exactly. Proving that in CI needs faults
//! that are themselves reproducible, so a [`FaultPlan`] is a **pure
//! schedule**: whether invocation `k` of a site faults is a function of
//! `(seed, site name, k, fault kind)` alone — an FNV-1a hash of the site
//! name mixed with the seed and invocation counter through the same
//! splitmix64 finaliser the crate's [`Pcg64`](crate::rng::Pcg64) seeds
//! with. Two runs with the same `EES_FAULT_SEED` inject at identical
//! sites; the schedule is exposed ([`FaultPlan::schedule`]) so tests can
//! pin it without tripping the faults.
//!
//! # Sites and kinds
//!
//! Injection points are named after the code they live in ([`SITES`]).
//! Each site supports three kinds, each with an independent invocation
//! counter:
//!
//! - **panic** — `panic!` with a recognizable [`PANIC_PREFIX`] message;
//!   exercises `catch_unwind` supervision and mutex poison recovery.
//! - **io** — a synthesized [`std::io::Error`]; exercises the bounded
//!   retry/backoff in [`atomic_write`] and connection teardown.
//! - **delay** — a bounded sleep (≤ [`MAX_DELAY_US`]); exercises deadlines
//!   without unbounded stalls.
//!
//! Rates (`site.kind = 0.08`) draw per invocation; deterministic one-shots
//! (`site.kind_at = 6`) fire at exactly that invocation index. Rate 0 with
//! no `_at` never fires, and a plan with no configured sites is **inert**:
//! every injection point is a single `Option` check
//! ([`FaultPlan::inert`]), so the layer is always compiled and provably
//! free when unused.
//!
//! # Configuration
//!
//! `[fault]` config keys beat `EES_FAULT_*` env vars (the repo-wide
//! precedence):
//!
//! ```toml
//! [fault]
//! seed = 7
//! serve.dispatch.panic = 0.08   # per-dispatch panic rate
//! risk.chunk.panic_at = 6       # panic at exactly chunk invocation 6
//! checkpoint.write.io = 0.5     # transient write errors (retried)
//! serve.tcp_read.delay_us = 5000
//! ```
//!
//! Env form: `EES_FAULT_SEED=7
//! EES_FAULT_SITES="serve.dispatch.panic=0.08,risk.chunk.panic_at=6"`.
//! Unknown sites or knobs fail loudly — a typo'd chaos run must not
//! silently test nothing.

use std::collections::BTreeMap;
use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use crate::config::Config;

/// Every valid injection site. Adding an injection point to the codebase
/// means adding its name here — configuring an unlisted site is an error,
/// so plans cannot silently rot when code moves.
pub const SITES: [&str; 5] = [
    "serve.queue",
    "serve.dispatch",
    "serve.tcp_read",
    "risk.chunk",
    "checkpoint.write",
];

/// Injected panics carry this prefix (followed by `site#invocation`), so
/// supervision code and test assertions can recognize them.
pub const PANIC_PREFIX: &str = "ees-fault: injected panic at ";

/// Ceiling on injected latency (µs): delays model slow I/O, not hangs.
pub const MAX_DELAY_US: u64 = 200_000;

/// Write attempts [`atomic_write`] makes before reporting the last error.
pub const WRITE_ATTEMPTS: u32 = 3;

/// The site every checkpoint/ledger write shares — one knob faults all
/// durable output paths.
pub const WRITE_SITE: &str = "checkpoint.write";

/// The three injectable failure kinds (each with its own per-site counter).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    Panic,
    Io,
    Delay,
}

/// Per-site knobs: a rate in [0, 1] and/or a one-shot invocation index per
/// kind, plus an optional site-local delay override.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
struct SiteSpec {
    panic_rate: f64,
    panic_at: Option<u64>,
    io_rate: f64,
    io_at: Option<u64>,
    delay_rate: f64,
    delay_at: Option<u64>,
    /// 0 = use the plan-wide `delay_us` default.
    delay_us: u64,
}

/// A site's knobs plus its live invocation counters. Counters are shared
/// across clones of the plan (the `Arc` in [`FaultPlan`]), so every worker
/// thread of a server advances one global per-site schedule.
#[derive(Debug, Default)]
struct SiteState {
    spec: SiteSpec,
    panic_calls: AtomicU64,
    io_calls: AtomicU64,
    delay_calls: AtomicU64,
}

#[derive(Debug)]
struct Inner {
    seed: u64,
    /// Plan-wide default injected delay (µs) for sites without their own.
    delay_us: u64,
    sites: BTreeMap<String, SiteState>,
}

/// A seeded, deterministic fault-injection schedule.
///
/// Cloning is cheap (an `Arc`) and clones share invocation counters — a
/// [`ServeConfig`](crate::serve::ServeConfig) cloned per worker still
/// drives one plan-wide schedule. The default plan is inert.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    inner: Option<Arc<Inner>>,
}

/// The pure fire decision for invocation `k` of `site`: a one-shot index
/// match, or a uniform draw under `rate` from the (seed, site, k, kind)
/// hash. No state — this is what makes the schedule reproducible.
fn fires(seed: u64, site: &str, k: u64, kind: FaultKind, rate: f64, at: Option<u64>) -> bool {
    if at == Some(k) {
        return true;
    }
    rate > 0.0 && unit(seed, site, k, kind) < rate
}

/// Uniform in [0, 1) from (seed, site, invocation, kind): FNV-1a over the
/// site name, mixed with the counter and kind tag, finalised by the same
/// splitmix64 the crate's generators seed through.
fn unit(seed: u64, site: &str, k: u64, kind: FaultKind) -> f64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in site.as_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut x = seed
        ^ h.rotate_left(17)
        ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ ((kind as u64 + 1) << 56);
    let z = crate::rng::splitmix64(&mut x);
    (z >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0)
}

impl FaultPlan {
    /// The no-fault plan: every injection point reduces to one `Option`
    /// check. This is the default everywhere a `[fault]` section is absent.
    pub fn inert() -> Self {
        FaultPlan { inner: None }
    }

    /// Whether any site is configured. An armed plan with all rates at 0
    /// still fires nothing — the determinism suite pins that an armed
    /// rate-0 plan is bitwise-invisible next to an inert one.
    pub fn is_armed(&self) -> bool {
        self.inner.is_some()
    }

    /// Build from `EES_FAULT_*` env vars alone (the [`global`] plan).
    pub fn from_env() -> crate::Result<Self> {
        let mut b = Builder::default();
        b.apply_env()?;
        Ok(b.build())
    }

    /// Build from a parsed config's `[fault]` section layered over the
    /// `EES_FAULT_*` env vars (config beats env, the repo-wide precedence).
    pub fn from_config(cfg: &Config) -> crate::Result<Self> {
        let mut b = Builder::default();
        b.apply_env()?;
        b.apply_config(cfg)?;
        Ok(b.build())
    }

    fn site(&self, site: &str) -> Option<(&Inner, &SiteState)> {
        let inner = self.inner.as_deref()?;
        inner.sites.get(site).map(|st| (inner, st))
    }

    /// Panic injection point: panics with [`PANIC_PREFIX`]`site#k` when the
    /// schedule fires at this site's next panic invocation. No-op on an
    /// inert plan or an unconfigured site.
    pub fn panic_point(&self, site: &str) {
        let Some((inner, st)) = self.site(site) else {
            return;
        };
        let k = st.panic_calls.fetch_add(1, Ordering::Relaxed);
        if fires(inner.seed, site, k, FaultKind::Panic, st.spec.panic_rate, st.spec.panic_at) {
            panic!("{PANIC_PREFIX}{site}#{k}");
        }
    }

    /// I/O-error injection point: returns a synthesized error when the
    /// schedule fires. Callers treat it exactly like a real transient I/O
    /// failure (retry, drop the connection, …).
    pub fn io_point(&self, site: &str) -> io::Result<()> {
        let Some((inner, st)) = self.site(site) else {
            return Ok(());
        };
        let k = st.io_calls.fetch_add(1, Ordering::Relaxed);
        if fires(inner.seed, site, k, FaultKind::Io, st.spec.io_rate, st.spec.io_at) {
            return Err(io::Error::new(
                io::ErrorKind::Other,
                format!("ees-fault: injected I/O error at {site}#{k}"),
            ));
        }
        Ok(())
    }

    /// Bounded-latency injection point: sleeps the site's `delay_us`
    /// (clamped to [`MAX_DELAY_US`]) when the schedule fires.
    pub fn delay_point(&self, site: &str) {
        let Some((inner, st)) = self.site(site) else {
            return;
        };
        let k = st.delay_calls.fetch_add(1, Ordering::Relaxed);
        if fires(inner.seed, site, k, FaultKind::Delay, st.spec.delay_rate, st.spec.delay_at) {
            let us = if st.spec.delay_us > 0 { st.spec.delay_us } else { inner.delay_us };
            std::thread::sleep(Duration::from_micros(us.min(MAX_DELAY_US)));
        }
    }

    /// The pure schedule: which invocation indices in `0..upto` fire for
    /// `(site, kind)`. Reads no counters and injects nothing — the
    /// determinism tests compare two plans' schedules with this.
    pub fn schedule(&self, site: &str, kind: FaultKind, upto: u64) -> Vec<u64> {
        let Some((inner, st)) = self.site(site) else {
            return Vec::new();
        };
        let (rate, at) = match kind {
            FaultKind::Panic => (st.spec.panic_rate, st.spec.panic_at),
            FaultKind::Io => (st.spec.io_rate, st.spec.io_at),
            FaultKind::Delay => (st.spec.delay_rate, st.spec.delay_at),
        };
        (0..upto).filter(|&k| fires(inner.seed, site, k, kind, rate, at)).collect()
    }
}

/// The process-global env-only plan, for write paths with no config in
/// scope (`--out` reports, train checkpoints). Malformed `EES_FAULT_*` is
/// reported once and disables injection instead of killing the run —
/// chaos knobs must never break a production process that ignores them.
pub fn global() -> &'static FaultPlan {
    static PLAN: OnceLock<FaultPlan> = OnceLock::new();
    PLAN.get_or_init(|| match FaultPlan::from_env() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("ees fault: {e} — EES_FAULT_* ignored, injection disabled");
            FaultPlan::inert()
        }
    })
}

/// Render a `catch_unwind` payload as text (panic messages are `&str` or
/// `String` in practice) — used to fold worker panics into explicit
/// `status:"failed"` responses.
pub fn panic_reason(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Crash-safe file write through the [`global`] env plan: see
/// [`atomic_write_with`].
pub fn atomic_write(path: &str, contents: &str) -> io::Result<()> {
    atomic_write_with(global(), path, contents)
}

/// Crash-safe file write: the bytes land in a `.tmp` sibling first and
/// reach `path` only through `fs::rename`, so a crash at any instant
/// leaves either the old complete file or the new complete file — never a
/// torn one. Transient failures (including injected [`WRITE_SITE`] faults)
/// are retried up to [`WRITE_ATTEMPTS`] times with a short deterministic
/// backoff; on persistent failure the target file is untouched and the
/// last error is returned.
pub fn atomic_write_with(plan: &FaultPlan, path: &str, contents: &str) -> io::Result<()> {
    let tmp = format!("{path}.tmp");
    let mut last_err: Option<io::Error> = None;
    for attempt in 0..WRITE_ATTEMPTS {
        let res = (|| {
            plan.io_point(WRITE_SITE)?;
            std::fs::write(&tmp, contents)?;
            std::fs::rename(&tmp, path)
        })();
        match res {
            Ok(()) => return Ok(()),
            Err(e) => {
                last_err = Some(e);
                // Deterministic bounded backoff: 2ms, 4ms — enough to ride
                // out transient filesystem hiccups, never a stall.
                if attempt + 1 < WRITE_ATTEMPTS {
                    std::thread::sleep(Duration::from_millis(2u64 << attempt));
                }
            }
        }
    }
    let _ = std::fs::remove_file(&tmp);
    Err(last_err.expect("WRITE_ATTEMPTS >= 1"))
}

/// Accumulates knobs from env and config before freezing into a plan.
#[derive(Default)]
struct Builder {
    seed: Option<u64>,
    delay_us: Option<u64>,
    specs: BTreeMap<String, SiteSpec>,
}

impl Builder {
    fn apply_env(&mut self) -> crate::Result<()> {
        if let Ok(v) = std::env::var("EES_FAULT_SEED") {
            self.seed = Some(v.trim().parse().map_err(|_| {
                crate::format_err!("EES_FAULT_SEED: not an unsigned integer: '{}'", v.trim())
            })?);
        }
        if let Ok(v) = std::env::var("EES_FAULT_DELAY_US") {
            self.delay_us = Some(v.trim().parse().map_err(|_| {
                crate::format_err!("EES_FAULT_DELAY_US: not an unsigned integer: '{}'", v.trim())
            })?);
        }
        if let Ok(v) = std::env::var("EES_FAULT_SITES") {
            self.apply_sites_str(&v, "EES_FAULT_SITES")?;
        }
        Ok(())
    }

    /// Parse the compact env form: `site.knob=value,site.knob=value,…`.
    fn apply_sites_str(&mut self, text: &str, src: &str) -> crate::Result<()> {
        for part in text.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part.split_once('=').ok_or_else(|| {
                crate::format_err!("{src}: expected site.knob=value, got '{part}'")
            })?;
            let num: f64 = val.trim().parse().map_err(|_| {
                crate::format_err!("{src}: not a number: '{}'", val.trim())
            })?;
            self.apply_knob(key.trim(), num, src)?;
        }
        Ok(())
    }

    fn apply_config(&mut self, cfg: &Config) -> crate::Result<()> {
        for (key, value) in &cfg.values {
            let Some(rest) = key.strip_prefix("fault.") else {
                continue;
            };
            let num = value.as_f64().ok_or_else(|| {
                crate::format_err!("[fault] {rest}: expected a number")
            })?;
            match rest {
                "seed" => self.seed = Some(int_knob(num, "seed", "[fault]")?),
                "delay_us" => self.delay_us = Some(int_knob(num, "delay_us", "[fault]")?),
                _ => self.apply_knob(rest, num, "[fault]")?,
            }
        }
        Ok(())
    }

    fn apply_knob(&mut self, key: &str, val: f64, src: &str) -> crate::Result<()> {
        let (site, knob) = key.rsplit_once('.').ok_or_else(|| {
            crate::format_err!("{src}: fault knob '{key}' should be <site>.<knob>")
        })?;
        if !SITES.contains(&site) {
            return Err(crate::format_err!(
                "{src}: unknown fault site '{site}' (sites: {})",
                SITES.join(", ")
            ));
        }
        let spec = self.specs.entry(site.to_string()).or_default();
        match knob {
            "panic" => spec.panic_rate = rate_knob(val, key, src)?,
            "io" => spec.io_rate = rate_knob(val, key, src)?,
            "delay" => spec.delay_rate = rate_knob(val, key, src)?,
            "panic_at" => spec.panic_at = Some(int_knob(val, key, src)?),
            "io_at" => spec.io_at = Some(int_knob(val, key, src)?),
            "delay_at" => spec.delay_at = Some(int_knob(val, key, src)?),
            "delay_us" => spec.delay_us = int_knob(val, key, src)?,
            other => {
                return Err(crate::format_err!(
                    "{src}: unknown fault knob '{other}' on site '{site}' \
                     (panic|io|delay|panic_at|io_at|delay_at|delay_us)"
                ))
            }
        }
        Ok(())
    }

    fn build(self) -> FaultPlan {
        if self.specs.is_empty() {
            return FaultPlan::inert();
        }
        let sites = self
            .specs
            .into_iter()
            .map(|(name, spec)| (name, SiteState { spec, ..SiteState::default() }))
            .collect();
        FaultPlan {
            inner: Some(Arc::new(Inner {
                seed: self.seed.unwrap_or(42),
                delay_us: self.delay_us.unwrap_or(1_000),
                sites,
            })),
        }
    }
}

fn rate_knob(val: f64, key: &str, src: &str) -> crate::Result<f64> {
    if val.is_finite() && (0.0..=1.0).contains(&val) {
        Ok(val)
    } else {
        Err(crate::format_err!("{src}: {key} must be a rate in [0, 1], got {val}"))
    }
}

fn int_knob(val: f64, key: &str, src: &str) -> crate::Result<u64> {
    if val.is_finite() && val >= 0.0 && val.fract() == 0.0 && val <= u64::MAX as f64 {
        Ok(val as u64)
    } else {
        Err(crate::format_err!("{src}: {key} must be a non-negative integer, got {val}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(fault_body: &str) -> FaultPlan {
        let text = format!("[fault]\n{fault_body}");
        FaultPlan::from_config(&Config::parse(&text).unwrap()).unwrap()
    }

    #[test]
    fn empty_section_is_inert() {
        let p = plan("seed = 3\n");
        assert!(!p.is_armed());
        let p = FaultPlan::from_config(&Config::parse("").unwrap()).unwrap();
        assert!(!p.is_armed());
        // Inert points are free no-ops.
        p.panic_point("serve.dispatch");
        assert!(p.io_point("checkpoint.write").is_ok());
        p.delay_point("risk.chunk");
    }

    #[test]
    fn schedule_is_a_pure_function_of_the_seed() {
        let a = plan("seed = 9\nserve.dispatch.panic = 0.1\n");
        let b = plan("seed = 9\nserve.dispatch.panic = 0.1\n");
        let c = plan("seed = 10\nserve.dispatch.panic = 0.1\n");
        let sa = a.schedule("serve.dispatch", FaultKind::Panic, 2000);
        assert_eq!(sa, b.schedule("serve.dispatch", FaultKind::Panic, 2000));
        assert_ne!(sa, c.schedule("serve.dispatch", FaultKind::Panic, 2000));
        // ~10% of 2000 draws fire, within a loose band.
        assert!(sa.len() > 120 && sa.len() < 280, "{} fired", sa.len());
        // Kinds draw independent streams at the same site.
        let si = plan("seed = 9\nserve.dispatch.io = 0.1\n");
        assert_ne!(sa, si.schedule("serve.dispatch", FaultKind::Io, 2000));
    }

    #[test]
    fn rate_bounds_and_one_shots() {
        let p = plan("serve.queue.panic = 0.0\n");
        assert!(p.is_armed());
        assert!(p.schedule("serve.queue", FaultKind::Panic, 5000).is_empty());
        let p = plan("serve.queue.panic = 1.0\n");
        assert_eq!(
            p.schedule("serve.queue", FaultKind::Panic, 100),
            (0..100).collect::<Vec<_>>()
        );
        let p = plan("risk.chunk.panic_at = 5\n");
        assert_eq!(p.schedule("risk.chunk", FaultKind::Panic, 100), vec![5]);
    }

    #[test]
    fn points_fire_as_scheduled() {
        let p = plan("serve.dispatch.panic_at = 1\n");
        p.panic_point("serve.dispatch"); // k = 0: clean
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.panic_point("serve.dispatch") // k = 1: fires
        }))
        .unwrap_err();
        let msg = panic_reason(&*err);
        assert!(msg.starts_with(PANIC_PREFIX), "{msg}");
        assert!(msg.contains("serve.dispatch#1"), "{msg}");

        let p = plan("checkpoint.write.io_at = 0\n");
        let e = p.io_point("checkpoint.write").unwrap_err();
        assert!(e.to_string().contains("injected I/O error"), "{e}");
        assert!(p.io_point("checkpoint.write").is_ok()); // k = 1: clean

        // Counters are shared across clones: the clone continues the
        // original's schedule instead of restarting it.
        let p = plan("serve.dispatch.panic_at = 1\n");
        p.panic_point("serve.dispatch"); // k = 0 on the original
        let clone = p.clone();
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            clone.panic_point("serve.dispatch") // k = 1 through the clone
        }))
        .is_err());
    }

    #[test]
    fn config_beats_env_shape_errors_fail_loudly() {
        for bad in [
            "serve.dispatch.panic = 1.5\n",        // rate out of range
            "serve.dispatch.panic = -0.1\n",       // negative rate
            "warp.core.panic = 0.5\n",             // unknown site
            "serve.dispatch.turbo = 0.5\n",        // unknown knob
            "serve.dispatch.panic_at = 1.5\n",     // fractional index
        ] {
            let text = format!("[fault]\n{bad}");
            assert!(
                FaultPlan::from_config(&Config::parse(&text).unwrap()).is_err(),
                "accepted: {bad}"
            );
        }
    }

    #[test]
    fn env_sites_string_parses() {
        let mut b = Builder::default();
        b.apply_sites_str(
            "serve.dispatch.panic=0.08, risk.chunk.panic_at=6",
            "EES_FAULT_SITES",
        )
        .unwrap();
        b.seed = Some(7);
        let p = b.build();
        assert!(p.is_armed());
        assert_eq!(p.schedule("risk.chunk", FaultKind::Panic, 100), vec![6]);
        assert!(!p.schedule("serve.dispatch", FaultKind::Panic, 1000).is_empty());

        let mut b = Builder::default();
        assert!(b.apply_sites_str("serve.dispatch.panic", "EES_FAULT_SITES").is_err());
        assert!(b.apply_sites_str("serve.dispatch.panic=x", "EES_FAULT_SITES").is_err());
    }

    #[test]
    fn atomic_write_lands_bytes_and_cleans_tmp() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ees_fault_aw_{}.txt", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let plan = FaultPlan::inert();
        atomic_write_with(&plan, &path, "hello\n").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "hello\n");
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn atomic_write_retries_transient_injected_failures() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ees_fault_retry_{}.txt", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        // First attempt faults, the retry succeeds.
        let p = plan("checkpoint.write.io_at = 0\n");
        atomic_write_with(&p, &path, "v2\n").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "v2\n");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn atomic_write_persistent_failure_keeps_the_old_file() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ees_fault_keep_{}.txt", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, "old\n").unwrap();
        let p = plan("checkpoint.write.io = 1.0\n");
        let err = atomic_write_with(&p, &path, "new\n").unwrap_err();
        assert!(err.to_string().contains("injected"), "{err}");
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "old\n");
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn delay_point_is_bounded() {
        let p = plan("serve.tcp_read.delay_at = 0\nserve.tcp_read.delay_us = 1\n");
        let t0 = std::time::Instant::now();
        p.delay_point("serve.tcp_read");
        assert!(t0.elapsed() < Duration::from_secs(1));
    }
}
