//! Homogeneous-space substrate for geometric integration.
//!
//! A [`HomogeneousSpace`] is a manifold M with a transitive Lie-group action
//! Λ: G × M → M; integrators only ever touch it through the *frozen flow*
//! `y ← Λ(exp(v), y)` for Lie-algebra elements v ∈ 𝔤 (expressed in a fixed
//! basis as `&[f64]`). This is exactly the interface needed by the
//! commutator-free lift (4) of the paper and by its cotangent-bundle adjoint
//! (Algorithm 2), which additionally needs the pullbacks of
//! Ψ(Y, v) = Λ(exp(v), Y) with respect to both arguments.
//!
//! Implementations: [`Euclidean`] ℝⁿ, [`Torus`] 𝕋ⁿ, [`TTorus`] T𝕋ⁿ ≅ 𝕋ⁿ×ℝⁿ,
//! [`So3`] SO(3) (Rodrigues closed form), [`SOn`] SO(n), and [`Sphere`]
//! Sⁿ⁻¹ ≅ SO(n)/SO(n−1).

mod euclidean;
mod so3;
mod son;
mod sphere;
mod torus;

pub use euclidean::Euclidean;
pub use so3::So3;
pub use son::SOn;
pub use sphere::Sphere;
pub use torus::{TTorus, Torus};

use crate::linalg::{lane_gather, lane_scatter};
use crate::memory::StepWorkspace;
use std::sync::atomic::{AtomicU64, Ordering};

/// Shared instrumentation: every space counts its group-exponential
/// evaluations so the cost model of Table 5 can be checked empirically.
#[derive(Default, Debug)]
pub struct ExpCounter(AtomicU64);

impl ExpCounter {
    pub fn bump(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    /// Count `k` exponentials at once (the lane-blocked kernels act on a
    /// whole lane group per call but must report per-sample costs).
    pub fn bump_many(&self, k: u64) {
        self.0.fetch_add(k, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

impl Clone for ExpCounter {
    fn clone(&self) -> Self {
        ExpCounter(AtomicU64::new(self.get()))
    }
}

/// A homogeneous space M = G/H with a chosen basis of 𝔤.
pub trait HomogeneousSpace: Send + Sync {
    /// Dimension of the ambient representation of a point of M.
    fn point_dim(&self) -> usize;
    /// Dimension of the Lie algebra 𝔤 (number of basis coefficients).
    fn algebra_dim(&self) -> usize;

    /// Frozen-flow step: y ← Λ(exp(v), y), v given in basis coordinates.
    fn exp_action(&self, v: &[f64], y: &mut [f64]);

    /// Numerical hygiene: re-impose the manifold constraint (no-op for exact
    /// representations such as angles on the torus).
    fn project(&self, _y: &mut [f64]) {}

    /// How far y is from the manifold (0 for flat spaces).
    fn constraint_defect(&self, _y: &[f64]) -> f64 {
        0.0
    }

    /// Pullbacks of Ψ(y, v) = Λ(exp(v), y) (Algorithm 2):
    /// given the cotangent `lam_out` of the output point, write
    /// `lam_y = (D_y Ψ)* lam_out` and `lam_v = (D_v Ψ)* lam_out`.
    /// `y` is the *input* point of the step.
    fn action_pullback(
        &self,
        v: &[f64],
        y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
    );

    /// Lie bracket [a, b] in basis coordinates (needed by RKMK's dexp⁻¹
    /// corrections; abelian groups return 0).
    fn bracket(&self, _a: &[f64], _b: &[f64], out: &mut [f64]) {
        out.fill(0.0);
    }

    /// Lane-blocked frozen flow: `v` is an `algebra_dim × lanes` and `y` a
    /// `point_dim × lanes` lane-major block (component `c` of lane `l` at
    /// `[c * lanes + l]`); advances every lane by its own algebra element.
    /// The default gathers each lane and runs the scalar [`Self::exp_action`]
    /// — bitwise-equal to per-sample stepping by construction — with the
    /// gather scratch drawn from the caller's `ws` (unlike the scalar path
    /// of the matrix spaces, which checks scratch out of an internal pool
    /// per call). Overrides must keep every per-lane float op in the scalar
    /// order; the lane width is a pure perf knob.
    fn exp_action_lanes(&self, v: &[f64], y: &mut [f64], lanes: usize, ws: &mut StepWorkspace) {
        let g = self.algebra_dim();
        let n = self.point_dim();
        debug_assert_eq!(v.len(), g * lanes);
        debug_assert_eq!(y.len(), n * lanes);
        let mut vl = ws.take(g);
        let mut yl = ws.take(n);
        for l in 0..lanes {
            lane_gather(v, l, lanes, &mut vl);
            lane_gather(y, l, lanes, &mut yl);
            self.exp_action(&vl, &mut yl);
            lane_scatter(&yl, l, lanes, y);
        }
        ws.put(yl);
        ws.put(vl);
    }

    /// Lane-blocked [`Self::action_pullback`]: all five slices are
    /// lane-major blocks (`lam_y`/`lam_out` of `point_dim × lanes`,
    /// `v`/`lam_v` of `algebra_dim × lanes`); lane `l` of the outputs is
    /// bitwise-equal to the scalar pullback on the gathered lane. Same
    /// overwrite semantics as the scalar method.
    fn action_pullback_lanes(
        &self,
        v: &[f64],
        y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
        lanes: usize,
        ws: &mut StepWorkspace,
    ) {
        let g = self.algebra_dim();
        let n = self.point_dim();
        let mut buf = ws.take(2 * g + 3 * n);
        {
            let (vl, rest) = buf.split_at_mut(g);
            let (lvl, rest) = rest.split_at_mut(g);
            let (yl, rest) = rest.split_at_mut(n);
            let (lol, lyl) = rest.split_at_mut(n);
            for l in 0..lanes {
                lane_gather(v, l, lanes, vl);
                lane_gather(y, l, lanes, yl);
                lane_gather(lam_out, l, lanes, lol);
                self.action_pullback(vl, yl, lol, lyl, lvl);
                lane_scatter(lyl, l, lanes, lam_y);
                lane_scatter(lvl, l, lanes, lam_v);
            }
        }
        ws.put(buf);
    }

    /// Number of group exponentials evaluated so far (instrumentation).
    fn exp_calls(&self) -> u64 {
        0
    }
    /// Reset the exponential counter.
    fn reset_exp_calls(&self) {}

    /// Geodesic-free distance used by losses/diagnostics (defaults to ℓ2 in
    /// the ambient representation; the torus overrides with wrapped distance).
    fn distance(&self, a: &[f64], b: &[f64]) -> f64 {
        a.iter()
            .zip(b.iter())
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f64>()
            .sqrt()
    }
}

/// Wrap an angle to (−π, π].
#[inline]
pub fn wrap_angle(t: f64) -> f64 {
    let two_pi = 2.0 * std::f64::consts::PI;
    let mut x = t % two_pi;
    if x <= -std::f64::consts::PI {
        x += two_pi;
    } else if x > std::f64::consts::PI {
        x -= two_pi;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// Frozen flows are exactly reversible (eq. 12): Λ(exp(−v), Λ(exp(v), y)) = y.
    #[test]
    fn frozen_flow_reversibility_all_spaces() {
        let mut rng = Pcg64::new(1);
        let spaces: Vec<Box<dyn HomogeneousSpace>> = vec![
            Box::new(Euclidean::new(5)),
            Box::new(Torus::new(4)),
            Box::new(TTorus::new(3)),
            Box::new(So3::new()),
            Box::new(SOn::new(4)),
            Box::new(Sphere::new(5)),
        ];
        for sp in &spaces {
            let mut y = random_point(sp.as_ref(), &mut rng);
            let y0 = y.clone();
            let mut v = vec![0.0; sp.algebra_dim()];
            rng.fill_normal_scaled(0.4, &mut v);
            sp.exp_action(&v, &mut y);
            let vneg: Vec<f64> = v.iter().map(|x| -x).collect();
            sp.exp_action(&vneg, &mut y);
            let err = y
                .iter()
                .zip(y0.iter())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(err < 1e-10, "space dim {} err {err}", sp.point_dim());
        }
    }

    /// exp_action keeps points on the manifold.
    #[test]
    fn action_preserves_constraints() {
        let mut rng = Pcg64::new(2);
        let spaces: Vec<Box<dyn HomogeneousSpace>> = vec![
            Box::new(So3::new()),
            Box::new(SOn::new(5)),
            Box::new(Sphere::new(16)),
        ];
        for sp in &spaces {
            let mut y = random_point(sp.as_ref(), &mut rng);
            for _ in 0..50 {
                let mut v = vec![0.0; sp.algebra_dim()];
                rng.fill_normal_scaled(0.3, &mut v);
                sp.exp_action(&v, &mut y);
            }
            assert!(
                sp.constraint_defect(&y) < 1e-9,
                "defect {}",
                sp.constraint_defect(&y)
            );
        }
    }

    /// Pullbacks match finite differences of the action (both arguments).
    #[test]
    fn action_pullback_matches_finite_difference() {
        let mut rng = Pcg64::new(3);
        let spaces: Vec<Box<dyn HomogeneousSpace>> = vec![
            Box::new(Euclidean::new(3)),
            Box::new(Torus::new(3)),
            Box::new(TTorus::new(2)),
            Box::new(So3::new()),
            Box::new(SOn::new(3)),
            Box::new(Sphere::new(4)),
        ];
        for sp in &spaces {
            let n = sp.point_dim();
            let g = sp.algebra_dim();
            let y = random_point(sp.as_ref(), &mut rng);
            let mut v = vec![0.0; g];
            rng.fill_normal_scaled(0.3, &mut v);
            let mut lam = vec![0.0; n];
            rng.fill_normal(&mut lam);

            let mut lam_y = vec![0.0; n];
            let mut lam_v = vec![0.0; g];
            sp.action_pullback(&v, &y, &lam, &mut lam_y, &mut lam_v);

            let f = |vv: &[f64], yy: &[f64]| -> f64 {
                let mut out = yy.to_vec();
                sp.exp_action(vv, &mut out);
                out.iter().zip(lam.iter()).map(|(a, b)| a * b).sum()
            };
            let eps = 1e-6;
            for k in 0..g {
                let mut vp = v.clone();
                vp[k] += eps;
                let mut vm = v.clone();
                vm[k] -= eps;
                let fd = (f(&vp, &y) - f(&vm, &y)) / (2.0 * eps);
                assert!(
                    (fd - lam_v[k]).abs() < 1e-5,
                    "dim {n} alg k={k}: fd {fd} vs {}",
                    lam_v[k]
                );
            }
            // NB: for embedded manifolds the y-derivative is only tested along
            // ambient directions; the pullback is the ambient-space adjoint.
            for k in 0..n {
                let mut yp = y.clone();
                yp[k] += eps;
                let mut ym = y.clone();
                ym[k] -= eps;
                let fd = (f(&v, &yp) - f(&v, &ym)) / (2.0 * eps);
                assert!(
                    (fd - lam_y[k]).abs() < 1e-5,
                    "dim {n} point k={k}: fd {fd} vs {}",
                    lam_y[k]
                );
            }
        }
    }

    /// The lane contract for every space: lane-blocked exp_action and
    /// action_pullback (default or override) are bitwise-equal to the
    /// scalar methods on each gathered lane.
    #[test]
    fn lane_action_and_pullback_match_scalar_bitwise() {
        let mut rng = Pcg64::new(7);
        let mut ws = StepWorkspace::new();
        let spaces: Vec<Box<dyn HomogeneousSpace>> = vec![
            Box::new(Euclidean::new(5)),
            Box::new(Torus::new(4)),
            Box::new(TTorus::new(3)),
            Box::new(So3::new()),
            Box::new(SOn::new(4)),
            Box::new(Sphere::new(5)),
        ];
        for sp in &spaces {
            let n = sp.point_dim();
            let g = sp.algebra_dim();
            for lanes in [1usize, 2, 4, 8] {
                // Per-lane scalar references.
                let ys: Vec<Vec<f64>> = (0..lanes)
                    .map(|_| random_point(sp.as_ref(), &mut rng))
                    .collect();
                let vs: Vec<Vec<f64>> = (0..lanes)
                    .map(|_| {
                        let mut v = vec![0.0; g];
                        rng.fill_normal_scaled(0.3, &mut v);
                        v
                    })
                    .collect();
                let lams: Vec<Vec<f64>> = (0..lanes)
                    .map(|_| {
                        let mut lam = vec![0.0; n];
                        rng.fill_normal(&mut lam);
                        lam
                    })
                    .collect();
                // Lane-major blocks.
                let mut yb = vec![0.0; n * lanes];
                let mut vb = vec![0.0; g * lanes];
                let mut lb = vec![0.0; n * lanes];
                for l in 0..lanes {
                    lane_scatter(&ys[l], l, lanes, &mut yb);
                    lane_scatter(&vs[l], l, lanes, &mut vb);
                    lane_scatter(&lams[l], l, lanes, &mut lb);
                }
                // Action.
                sp.exp_action_lanes(&vb, &mut yb, lanes, &mut ws);
                let mut got = vec![0.0; n];
                for l in 0..lanes {
                    let mut want = ys[l].clone();
                    sp.exp_action(&vs[l], &mut want);
                    lane_gather(&yb, l, lanes, &mut got);
                    for (u, v) in got.iter().zip(want.iter()) {
                        assert_eq!(u.to_bits(), v.to_bits(), "action n={n} lanes={lanes} l={l}");
                    }
                }
                // Pullback (at the pre-action points).
                let mut yb = vec![0.0; n * lanes];
                for l in 0..lanes {
                    lane_scatter(&ys[l], l, lanes, &mut yb);
                }
                let mut ly = vec![0.0; n * lanes];
                let mut lv = vec![0.0; g * lanes];
                sp.action_pullback_lanes(&vb, &yb, &lb, &mut ly, &mut lv, lanes, &mut ws);
                let mut got_y = vec![0.0; n];
                let mut got_v = vec![0.0; g];
                for l in 0..lanes {
                    let mut want_y = vec![0.0; n];
                    let mut want_v = vec![0.0; g];
                    sp.action_pullback(&vs[l], &ys[l], &lams[l], &mut want_y, &mut want_v);
                    lane_gather(&ly, l, lanes, &mut got_y);
                    lane_gather(&lv, l, lanes, &mut got_v);
                    for (u, v) in got_y.iter().zip(want_y.iter()) {
                        assert_eq!(u.to_bits(), v.to_bits(), "lam_y n={n} lanes={lanes} l={l}");
                    }
                    for (u, v) in got_v.iter().zip(want_v.iter()) {
                        assert_eq!(u.to_bits(), v.to_bits(), "lam_v n={n} lanes={lanes} l={l}");
                    }
                }
            }
        }
    }

    pub(super) fn random_point(sp: &dyn HomogeneousSpace, rng: &mut Pcg64) -> Vec<f64> {
        let n = sp.point_dim();
        // Start from a canonical point and randomise by group actions.
        let mut y = canonical_point(sp, n);
        for _ in 0..3 {
            let mut v = vec![0.0; sp.algebra_dim()];
            rng.fill_normal_scaled(0.5, &mut v);
            sp.exp_action(&v, &mut y);
        }
        y
    }

    fn canonical_point(sp: &dyn HomogeneousSpace, n: usize) -> Vec<f64> {
        // Heuristic: identity matrix for square reps, e1 for sphere, 0 else.
        let r = (n as f64).sqrt() as usize;
        if r * r == n && r > 1 && sp.constraint_defect(&crate::linalg::eye(r)) < 1e-12 {
            return crate::linalg::eye(r);
        }
        let mut y = vec![0.0; n];
        y[0] = 1.0;
        if sp.constraint_defect(&y) < 1e-12 {
            return y;
        }
        vec![0.0; n]
    }

    #[test]
    fn wrap_angle_range() {
        for i in -20..20 {
            let t = i as f64 * 0.7;
            let w = wrap_angle(t);
            assert!(w > -std::f64::consts::PI - 1e-12 && w <= std::f64::consts::PI + 1e-12);
            // Same point on the circle.
            assert!(((t - w) / (2.0 * std::f64::consts::PI)).fract().abs() < 1e-9);
        }
    }
}
