//! SO(n) with scaling-and-squaring exponential — substrate for the sphere
//! Sⁿ⁻¹ ≅ SO(n)/SO(n−1) and for general rotation-valued problems.
//!
//! Algebra basis: skew matrices E_{ij} = e_i e_jᵀ − e_j e_iᵀ for i < j in
//! lexicographic order, so `algebra_dim = n(n−1)/2`.

use super::{ExpCounter, HomogeneousSpace};
use crate::linalg::{
    expm_frechet_adjoint_into, expm_into, expm_lanes_into, lane_gather, lane_scatter, matmul,
    orthogonality_defect, transpose_into,
};
use crate::memory::{StepWorkspace, WorkspacePool};

#[derive(Debug)]
pub struct SOn {
    n: usize,
    exps: ExpCounter,
    /// Per-caller scratch (hat/exp/Fréchet panels) checked out per call so
    /// the space stays `Sync` without serialising workers.
    scratch: WorkspacePool,
}

impl Clone for SOn {
    fn clone(&self) -> Self {
        Self {
            n: self.n,
            exps: self.exps.clone(),
            scratch: WorkspacePool::new(),
        }
    }
}

impl SOn {
    pub fn new(n: usize) -> Self {
        assert!(n >= 2);
        Self {
            n,
            exps: ExpCounter::default(),
            scratch: WorkspacePool::new(),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Coefficients → skew matrix.
    pub fn hat(&self, v: &[f64], out: &mut [f64]) {
        let n = self.n;
        out.fill(0.0);
        let mut k = 0;
        for i in 0..n {
            for j in i + 1..n {
                out[i * n + j] = v[k];
                out[j * n + i] = -v[k];
                k += 1;
            }
        }
    }

    /// Skew matrix → coefficients (reads the upper triangle).
    pub fn vee(&self, m: &[f64], out: &mut [f64]) {
        let n = self.n;
        let mut k = 0;
        for i in 0..n {
            for j in i + 1..n {
                out[k] = m[i * n + j];
                k += 1;
            }
        }
    }

    /// Contraction of a general matrix M against the basis:
    /// ⟨M, E_{ij}⟩_F = M_ij − M_ji.
    pub fn basis_contract(&self, m: &[f64], out: &mut [f64]) {
        let n = self.n;
        let mut k = 0;
        for i in 0..n {
            for j in i + 1..n {
                out[k] = m[i * n + j] - m[j * n + i];
                k += 1;
            }
        }
    }
}

impl HomogeneousSpace for SOn {
    fn point_dim(&self) -> usize {
        self.n * self.n
    }
    fn algebra_dim(&self) -> usize {
        self.n * (self.n - 1) / 2
    }

    fn exp_action(&self, v: &[f64], y: &mut [f64]) {
        self.exps.bump();
        let n = self.n;
        self.scratch.with(|ws: &mut StepWorkspace| {
            let mut vh = ws.take(n * n);
            self.hat(v, &mut vh);
            let mut e = ws.take(n * n);
            expm_into(&vh, &mut e, n, ws);
            let mut out = ws.take(n * n);
            matmul(&e, y, &mut out, n, n, n);
            y.copy_from_slice(&out);
            ws.put(out);
            ws.put(e);
            ws.put(vh);
        });
    }

    fn project(&self, y: &mut [f64]) {
        let n = self.n;
        // Newton polar iteration: R ← R(3I − RᵀR)/2, twice.
        self.scratch.with(|ws: &mut StepWorkspace| {
            let mut rt = ws.take(n * n);
            let mut rtr = ws.take(n * n);
            let mut corr = ws.take(n * n);
            let mut out = ws.take(n * n);
            for _ in 0..2 {
                transpose_into(y, &mut rt, n, n);
                matmul(&rt, y, &mut rtr, n, n, n);
                for i in 0..n {
                    for j in 0..n {
                        corr[i * n + j] = -0.5 * rtr[i * n + j];
                    }
                    corr[i * n + i] += 1.5;
                }
                matmul(y, &corr, &mut out, n, n, n);
                y.copy_from_slice(&out);
            }
            ws.put(out);
            ws.put(corr);
            ws.put(rtr);
            ws.put(rt);
        });
    }

    fn constraint_defect(&self, y: &[f64]) -> f64 {
        orthogonality_defect(y, self.n)
    }

    fn action_pullback(
        &self,
        v: &[f64],
        y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
    ) {
        let n = self.n;
        self.scratch.with(|ws: &mut StepWorkspace| {
            let mut vh = ws.take(n * n);
            self.hat(v, &mut vh);
            let mut e = ws.take(n * n);
            expm_into(&vh, &mut e, n, ws);
            let mut et = ws.take(n * n);
            transpose_into(&e, &mut et, n, n);
            matmul(&et, lam_out, lam_y, n, n, n);
            // ⟨λ, dE·Y⟩ = ⟨λYᵀ, dE⟩, dE = L_{v̂}(hat(dv)).
            let mut yt = ws.take(n * n);
            transpose_into(y, &mut yt, n, n);
            let mut w = ws.take(n * n);
            matmul(lam_out, &yt, &mut w, n, n, n);
            let mut lstar = ws.take(n * n);
            expm_frechet_adjoint_into(&vh, &w, &mut lstar, n, ws);
            self.basis_contract(&lstar, lam_v);
            ws.put(lstar);
            ws.put(w);
            ws.put(yt);
            ws.put(et);
            ws.put(e);
            ws.put(vh);
        });
    }

    /// Lane-blocked frozen flow: lane-major hat block → batched
    /// [`expm_lanes_into`] panel → per-lane left multiplication, with all
    /// scratch from the caller's `ws` (no per-call internal pool checkout).
    fn exp_action_lanes(&self, v: &[f64], y: &mut [f64], lanes: usize, ws: &mut StepWorkspace) {
        self.exps.bump_many(lanes as u64);
        let n = self.n;
        let nn = n * n;
        let mut vh = ws.take(nn * lanes);
        let mut k = 0;
        for i in 0..n {
            for j in i + 1..n {
                for l in 0..lanes {
                    let vk = v[k * lanes + l];
                    vh[(i * n + j) * lanes + l] = vk;
                    vh[(j * n + i) * lanes + l] = -vk;
                }
                k += 1;
            }
        }
        let mut e = ws.take(nn * lanes);
        expm_lanes_into(&vh, &mut e, n, lanes, ws);
        let mut panel = ws.take(3 * nn);
        {
            let (el, rest) = panel.split_at_mut(nn);
            let (yl, out) = rest.split_at_mut(nn);
            for l in 0..lanes {
                lane_gather(&e, l, lanes, el);
                lane_gather(y, l, lanes, yl);
                matmul(el, yl, out, n, n, n);
                lane_scatter(out, l, lanes, y);
            }
        }
        ws.put(panel);
        ws.put(e);
        ws.put(vh);
    }

    /// Per-lane pullback replicating the scalar body op for op, panels from
    /// one contiguous `ws` checkout.
    fn action_pullback_lanes(
        &self,
        v: &[f64],
        y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
        lanes: usize,
        ws: &mut StepWorkspace,
    ) {
        let n = self.n;
        let g = self.algebra_dim();
        let nn = n * n;
        let mut panel = ws.take(9 * nn + 2 * g);
        {
            let (vh, rest) = panel.split_at_mut(nn);
            let (e, rest) = rest.split_at_mut(nn);
            let (et, rest) = rest.split_at_mut(nn);
            let (yt, rest) = rest.split_at_mut(nn);
            let (w, rest) = rest.split_at_mut(nn);
            let (lstar, rest) = rest.split_at_mut(nn);
            let (yl, rest) = rest.split_at_mut(nn);
            let (lol, rest) = rest.split_at_mut(nn);
            let (lyl, rest) = rest.split_at_mut(nn);
            let (vl, lvl) = rest.split_at_mut(g);
            for l in 0..lanes {
                lane_gather(v, l, lanes, vl);
                lane_gather(y, l, lanes, yl);
                lane_gather(lam_out, l, lanes, lol);
                self.hat(vl, vh);
                expm_into(vh, e, n, ws);
                transpose_into(e, et, n, n);
                matmul(et, lol, lyl, n, n, n);
                transpose_into(yl, yt, n, n);
                matmul(lol, yt, w, n, n, n);
                expm_frechet_adjoint_into(vh, w, lstar, n, ws);
                self.basis_contract(lstar, lvl);
                lane_scatter(lyl, l, lanes, lam_y);
                lane_scatter(lvl, l, lanes, lam_v);
            }
        }
        ws.put(panel);
    }

    /// Matrix commutator in the E_{ij} basis.
    fn bracket(&self, a: &[f64], b: &[f64], out: &mut [f64]) {
        let n = self.n;
        self.scratch.with(|ws: &mut StepWorkspace| {
            let mut ah = ws.take(n * n);
            let mut bh = ws.take(n * n);
            self.hat(a, &mut ah);
            self.hat(b, &mut bh);
            let mut ab = ws.take(n * n);
            let mut ba = ws.take(n * n);
            matmul(&ah, &bh, &mut ab, n, n, n);
            matmul(&bh, &ah, &mut ba, n, n, n);
            for (x, y) in ab.iter_mut().zip(ba.iter()) {
                *x -= y;
            }
            self.vee(&ab, out);
            ws.put(ba);
            ws.put(ab);
            ws.put(bh);
            ws.put(ah);
        });
    }

    fn exp_calls(&self) -> u64 {
        self.exps.get()
    }
    fn reset_exp_calls(&self) {
        self.exps.reset()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eye;

    #[test]
    fn hat_vee_round_trip() {
        let g = SOn::new(4);
        let v: Vec<f64> = (0..6).map(|i| i as f64 * 0.1 - 0.25).collect();
        let mut m = vec![0.0; 16];
        g.hat(&v, &mut m);
        // Skew check.
        for i in 0..4 {
            for j in 0..4 {
                assert!((m[i * 4 + j] + m[j * 4 + i]).abs() < 1e-15);
            }
        }
        let mut v2 = vec![0.0; 6];
        g.vee(&m, &mut v2);
        assert_eq!(v, v2);
    }

    #[test]
    fn so3_embedding_consistency() {
        // SO(3) via SOn must agree with the Rodrigues path up to basis relabel:
        // basis (E01, E02, E12) corresponds to hat coefficients (−w3, w2, −w1).
        let g = SOn::new(3);
        let w = [0.3, -0.2, 0.5]; // Rodrigues vector
        let v = [-w[2], w[1], -w[0]];
        let mut y = eye(3);
        g.exp_action(&v, &mut y);
        let e = crate::linalg::so3_exp(&w);
        for i in 0..9 {
            assert!((y[i] - e[i]).abs() < 1e-12, "{i}");
        }
    }

    #[test]
    fn exp_action_orthogonal_n6() {
        let g = SOn::new(6);
        let mut rng = crate::rng::Pcg64::new(1);
        let mut y = eye(6);
        for _ in 0..10 {
            let mut v = vec![0.0; g.algebra_dim()];
            rng.fill_normal_scaled(0.5, &mut v);
            g.exp_action(&v, &mut y);
        }
        assert!(g.constraint_defect(&y) < 1e-10);
    }
}
