//! SO(3) with the Rodrigues closed-form exponential — the group used by the
//! CF-EES convergence experiment on the SO(3) RDE (Appendix G, Figure 8).
//!
//! Points are rotation matrices R (row-major 3×3, 9 floats); the algebra
//! 𝔰𝔬(3) is identified with ℝ³ through the hat map. The action is left
//! multiplication, Λ(exp(v̂), R) = exp(v̂)·R.

use super::{ExpCounter, HomogeneousSpace};
use crate::linalg::{
    expm_frechet_adjoint_into, mat3mul, matmul, orthogonality_defect, so3_exp, so3_hat,
    transpose_into,
};
use crate::memory::{StepWorkspace, WorkspacePool};

#[derive(Debug)]
pub struct So3 {
    exps: ExpCounter,
    /// Per-caller scratch for the Fréchet-adjoint pullback, checked out per
    /// call so the space stays `Sync` without serialising workers.
    scratch: WorkspacePool,
}

impl So3 {
    pub fn new() -> Self {
        Self {
            exps: ExpCounter::default(),
            scratch: WorkspacePool::new(),
        }
    }
}

impl Clone for So3 {
    fn clone(&self) -> Self {
        Self {
            exps: self.exps.clone(),
            scratch: WorkspacePool::new(),
        }
    }
}

impl Default for So3 {
    fn default() -> Self {
        Self::new()
    }
}

impl HomogeneousSpace for So3 {
    fn point_dim(&self) -> usize {
        9
    }
    fn algebra_dim(&self) -> usize {
        3
    }

    fn exp_action(&self, v: &[f64], y: &mut [f64]) {
        self.exps.bump();
        let e = so3_exp(v);
        let out = mat3mul(&e, y);
        y.copy_from_slice(&out);
    }

    fn project(&self, y: &mut [f64]) {
        // One Newton step of the polar projection: R ← R(3I − RᵀR)/2.
        let mut rt = [0.0f64; 9];
        transpose_into(y, &mut rt, 3, 3);
        let mut rtr = [0.0f64; 9];
        matmul(&rt, y, &mut rtr, 3, 3, 3);
        let mut corr = [0.0f64; 9];
        for i in 0..3 {
            for j in 0..3 {
                corr[i * 3 + j] = -0.5 * rtr[i * 3 + j];
            }
            corr[i * 3 + i] += 1.5;
        }
        let mut out = [0.0f64; 9];
        matmul(y, &corr, &mut out, 3, 3, 3);
        y.copy_from_slice(&out);
    }

    fn constraint_defect(&self, y: &[f64]) -> f64 {
        orthogonality_defect(y, 3)
    }

    fn action_pullback(
        &self,
        v: &[f64],
        y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
    ) {
        // Output = E(v)·Y with E = exp(v̂).
        // λ_Y = Eᵀ λ_out (matrix cotangent contracted through left mult):
        //   ⟨λ_out, E dY⟩_F = ⟨Eᵀ λ_out, dY⟩_F.
        let e = so3_exp(v);
        let mut et = [0.0f64; 9];
        transpose_into(&e, &mut et, 3, 3);
        let mut tmp = [0.0f64; 9];
        matmul(&et, lam_out, &mut tmp, 3, 3, 3);
        lam_y.copy_from_slice(&tmp);
        // λ_v: ⟨λ_out, dE·Y⟩ = ⟨λ_out Yᵀ, dE⟩ with dE = L_{v̂}(hat(dv)).
        let mut yt = [0.0f64; 9];
        transpose_into(y, &mut yt, 3, 3);
        let mut w = [0.0f64; 9];
        matmul(lam_out, &yt, &mut w, 3, 3, 3);
        self.scratch.with(|ws: &mut StepWorkspace| {
            let mut lstar = ws.take(9);
            expm_frechet_adjoint_into(&so3_hat(v), &w, &mut lstar, 3, ws);
            // Contract against the hat basis: ⟨M, hat(e_k)⟩_F.
            lam_v[0] = lstar[7] - lstar[5]; // M32 - M23
            lam_v[1] = lstar[2] - lstar[6]; // M13 - M31
            lam_v[2] = lstar[3] - lstar[1]; // M21 - M12
            ws.put(lstar);
        });
    }

    /// Per-lane Rodrigues straight off the lane-major block — all scratch
    /// is stack 3×3 arrays, no pool checkout, no gather buffers. Each
    /// lane's op sequence is exactly the scalar [`Self::exp_action`].
    fn exp_action_lanes(&self, v: &[f64], y: &mut [f64], lanes: usize, _ws: &mut StepWorkspace) {
        self.exps.bump_many(lanes as u64);
        for l in 0..lanes {
            let w = [v[l], v[lanes + l], v[2 * lanes + l]];
            let e = so3_exp(&w);
            let mut r = [0.0f64; 9];
            for (i, ri) in r.iter_mut().enumerate() {
                *ri = y[i * lanes + l];
            }
            let out = mat3mul(&e, &r);
            for (i, oi) in out.iter().enumerate() {
                y[i * lanes + l] = *oi;
            }
        }
    }

    /// Per-lane pullback with stack 3×3 scratch; only the Fréchet-adjoint
    /// panel comes from the caller's `ws` (one checkout for the whole lane
    /// group, instead of the scalar path's per-call pool checkout).
    fn action_pullback_lanes(
        &self,
        v: &[f64],
        y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
        lanes: usize,
        ws: &mut StepWorkspace,
    ) {
        let mut lstar = ws.take(9);
        for l in 0..lanes {
            let w3 = [v[l], v[lanes + l], v[2 * lanes + l]];
            let e = so3_exp(&w3);
            let mut et = [0.0f64; 9];
            transpose_into(&e, &mut et, 3, 3);
            let mut lo = [0.0f64; 9];
            for (i, x) in lo.iter_mut().enumerate() {
                *x = lam_out[i * lanes + l];
            }
            let mut tmp = [0.0f64; 9];
            matmul(&et, &lo, &mut tmp, 3, 3, 3);
            for (i, x) in tmp.iter().enumerate() {
                lam_y[i * lanes + l] = *x;
            }
            let mut yl = [0.0f64; 9];
            for (i, x) in yl.iter_mut().enumerate() {
                *x = y[i * lanes + l];
            }
            let mut yt = [0.0f64; 9];
            transpose_into(&yl, &mut yt, 3, 3);
            let mut w = [0.0f64; 9];
            matmul(&lo, &yt, &mut w, 3, 3, 3);
            expm_frechet_adjoint_into(&so3_hat(&w3), &w, &mut lstar, 3, ws);
            lam_v[l] = lstar[7] - lstar[5];
            lam_v[lanes + l] = lstar[2] - lstar[6];
            lam_v[2 * lanes + l] = lstar[3] - lstar[1];
        }
        ws.put(lstar);
    }

    /// 𝔰𝔬(3) bracket is the cross product under the hat identification.
    fn bracket(&self, a: &[f64], b: &[f64], out: &mut [f64]) {
        out[0] = a[1] * b[2] - a[2] * b[1];
        out[1] = a[2] * b[0] - a[0] * b[2];
        out[2] = a[0] * b[1] - a[1] * b[0];
    }

    fn exp_calls(&self) -> u64 {
        self.exps.get()
    }
    fn reset_exp_calls(&self) {
        self.exps.reset()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eye;

    #[test]
    fn action_from_identity_is_exp() {
        let g = So3::new();
        let mut y = eye(3);
        let v = [0.2, -0.1, 0.4];
        g.exp_action(&v, &mut y);
        let e = so3_exp(&v);
        for i in 0..9 {
            assert!((y[i] - e[i]).abs() < 1e-14);
        }
    }

    #[test]
    fn project_restores_orthogonality() {
        let g = So3::new();
        let mut y = eye(3);
        // Perturb off the manifold.
        y[1] += 1e-4;
        y[5] -= 2e-4;
        let before = g.constraint_defect(&y);
        g.project(&mut y);
        let after = g.constraint_defect(&y);
        assert!(after < before * 1e-2, "before {before} after {after}");
    }

    #[test]
    fn composition_matches_bch_first_order() {
        // exp(u)exp(v) ≈ exp(u+v) for small non-commuting u, v.
        let g = So3::new();
        let mut y = eye(3);
        let (u, v) = ([1e-4, 0.0, 0.0], [0.0, 1e-4, 0.0]);
        g.exp_action(&v, &mut y);
        g.exp_action(&u, &mut y);
        let direct = so3_exp(&[1e-4, 1e-4, 0.0]);
        for i in 0..9 {
            assert!((y[i] - direct[i]).abs() < 1e-7);
        }
    }
}
