//! The unit sphere Sⁿ⁻¹ ≅ SO(n)/SO(n−1) — state space of the latent SDE
//! experiment (Section 4, "Latent SDE on the sphere", S¹⁵ with n = 16).
//!
//! Points are unit vectors y ∈ ℝⁿ; the group SO(n) acts by matrix
//! multiplication, so the frozen flow is y ← exp(V)·y with V ∈ 𝔰𝔬(n).
//! Note the isotropy degeneracy of Example C.1: generators differing by an
//! element of 𝔰𝔬(n−1)_y act identically at y — the generator maps in
//! `models::sphere_lsde` fix the rank-2 representative V = a yᵀ − y aᵀ.

use super::{ExpCounter, HomogeneousSpace};
use crate::linalg::{
    expm_frechet_adjoint_into, expm_into, expm_lanes_into, lane_gather, lane_scatter, matvec,
    matvec_t, norm2,
};
use crate::memory::{StepWorkspace, WorkspacePool};

#[derive(Debug)]
pub struct Sphere {
    /// Ambient dimension n (the sphere is Sⁿ⁻¹).
    n: usize,
    exps: ExpCounter,
    /// Per-caller scratch (hat panel, exp panel, Fréchet blocks) checked out
    /// per call so the space stays `Sync` without serialising workers.
    scratch: WorkspacePool,
}

impl Clone for Sphere {
    fn clone(&self) -> Self {
        Self {
            n: self.n,
            exps: self.exps.clone(),
            scratch: WorkspacePool::new(),
        }
    }
}

impl Sphere {
    pub fn new(n: usize) -> Self {
        assert!(n >= 2);
        Self {
            n,
            exps: ExpCounter::default(),
            scratch: WorkspacePool::new(),
        }
    }

    pub fn ambient_dim(&self) -> usize {
        self.n
    }

    /// Rank-2 generator for the tangent direction `a` at `y` (a ⊥ y):
    /// coefficients of V = a yᵀ − y aᵀ in the E_{ij} basis.
    pub fn tangent_generator(&self, a: &[f64], y: &[f64], out: &mut [f64]) {
        let n = self.n;
        let mut k = 0;
        for i in 0..n {
            for j in i + 1..n {
                out[k] = a[i] * y[j] - y[i] * a[j];
                k += 1;
            }
        }
    }

    fn hat(&self, v: &[f64], out: &mut [f64]) {
        let n = self.n;
        out.fill(0.0);
        let mut k = 0;
        for i in 0..n {
            for j in i + 1..n {
                out[i * n + j] = v[k];
                out[j * n + i] = -v[k];
                k += 1;
            }
        }
    }
}

impl HomogeneousSpace for Sphere {
    fn point_dim(&self) -> usize {
        self.n
    }
    fn algebra_dim(&self) -> usize {
        self.n * (self.n - 1) / 2
    }

    fn exp_action(&self, v: &[f64], y: &mut [f64]) {
        self.exps.bump();
        let n = self.n;
        self.scratch.with(|ws: &mut StepWorkspace| {
            let mut vh = ws.take(n * n);
            self.hat(v, &mut vh);
            let mut e = ws.take(n * n);
            expm_into(&vh, &mut e, n, ws);
            let mut out = ws.take(n);
            matvec(&e, y, &mut out, n, n);
            y.copy_from_slice(&out);
            ws.put(out);
            ws.put(e);
            ws.put(vh);
        });
    }

    fn project(&self, y: &mut [f64]) {
        let nrm = norm2(y);
        if nrm > 0.0 {
            for yi in y.iter_mut() {
                *yi /= nrm;
            }
        }
    }

    fn constraint_defect(&self, y: &[f64]) -> f64 {
        (norm2(y) - 1.0).abs()
    }

    fn action_pullback(
        &self,
        v: &[f64],
        y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
    ) {
        let n = self.n;
        self.scratch.with(|ws: &mut StepWorkspace| {
            let mut vh = ws.take(n * n);
            self.hat(v, &mut vh);
            let mut e = ws.take(n * n);
            expm_into(&vh, &mut e, n, ws);
            // λ_y = Eᵀ λ_out.
            matvec_t(&e, lam_out, lam_y, n, n);
            // ⟨λ, dE·y⟩ = ⟨λ yᵀ, dE⟩ with λ yᵀ an n×n rank-1 cotangent.
            let mut w = ws.take(n * n);
            for i in 0..n {
                for j in 0..n {
                    w[i * n + j] = lam_out[i] * y[j];
                }
            }
            let mut lstar = ws.take(n * n);
            expm_frechet_adjoint_into(&vh, &w, &mut lstar, n, ws);
            let mut k = 0;
            for i in 0..n {
                for j in i + 1..n {
                    lam_v[k] = lstar[i * n + j] - lstar[j * n + i];
                    k += 1;
                }
            }
            ws.put(lstar);
            ws.put(w);
            ws.put(e);
            ws.put(vh);
        });
    }

    /// Lane-blocked frozen flow: builds the lane-major hat block, runs the
    /// batched [`expm_lanes_into`] panel (per-lane bitwise-equal to the
    /// scalar exponential), then rotates each lane's point. All scratch
    /// comes from the caller's `ws` in one set of checkouts — no per-call
    /// internal pool checkout, the scalar path's per-lane overhead.
    fn exp_action_lanes(&self, v: &[f64], y: &mut [f64], lanes: usize, ws: &mut StepWorkspace) {
        self.exps.bump_many(lanes as u64);
        let n = self.n;
        let mut vh = ws.take(n * n * lanes);
        let mut k = 0;
        for i in 0..n {
            for j in i + 1..n {
                for l in 0..lanes {
                    let vk = v[k * lanes + l];
                    vh[(i * n + j) * lanes + l] = vk;
                    vh[(j * n + i) * lanes + l] = -vk;
                }
                k += 1;
            }
        }
        let mut e = ws.take(n * n * lanes);
        expm_lanes_into(&vh, &mut e, n, lanes, ws);
        let mut panel = ws.take(n * n + 2 * n);
        {
            let (el, rest) = panel.split_at_mut(n * n);
            let (yl, out) = rest.split_at_mut(n);
            for l in 0..lanes {
                lane_gather(&e, l, lanes, el);
                lane_gather(y, l, lanes, yl);
                matvec(el, yl, out, n, n);
                lane_scatter(out, l, lanes, y);
            }
        }
        ws.put(panel);
        ws.put(e);
        ws.put(vh);
    }

    /// Per-lane pullback replicating the scalar body op for op, with every
    /// panel drawn from the caller's `ws` in one contiguous checkout.
    fn action_pullback_lanes(
        &self,
        v: &[f64],
        y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
        lanes: usize,
        ws: &mut StepWorkspace,
    ) {
        let n = self.n;
        let g = self.algebra_dim();
        let nn = n * n;
        let mut panel = ws.take(4 * nn + 3 * n + 2 * g);
        {
            let (vh, rest) = panel.split_at_mut(nn);
            let (e, rest) = rest.split_at_mut(nn);
            let (w, rest) = rest.split_at_mut(nn);
            let (lstar, rest) = rest.split_at_mut(nn);
            let (yl, rest) = rest.split_at_mut(n);
            let (lol, rest) = rest.split_at_mut(n);
            let (lyl, rest) = rest.split_at_mut(n);
            let (vl, lvl) = rest.split_at_mut(g);
            for l in 0..lanes {
                lane_gather(v, l, lanes, vl);
                lane_gather(y, l, lanes, yl);
                lane_gather(lam_out, l, lanes, lol);
                self.hat(vl, vh);
                expm_into(vh, e, n, ws);
                matvec_t(e, lol, lyl, n, n);
                for i in 0..n {
                    for j in 0..n {
                        w[i * n + j] = lol[i] * yl[j];
                    }
                }
                expm_frechet_adjoint_into(vh, w, lstar, n, ws);
                let mut k = 0;
                for i in 0..n {
                    for j in i + 1..n {
                        lvl[k] = lstar[i * n + j] - lstar[j * n + i];
                        k += 1;
                    }
                }
                lane_scatter(lyl, l, lanes, lam_y);
                lane_scatter(lvl, l, lanes, lam_v);
            }
        }
        ws.put(panel);
    }

    /// 𝔰𝔬(n) matrix commutator in the E_{ij} basis.
    fn bracket(&self, a: &[f64], b: &[f64], out: &mut [f64]) {
        let n = self.n;
        self.scratch.with(|ws: &mut StepWorkspace| {
            let mut ah = ws.take(n * n);
            let mut bh = ws.take(n * n);
            self.hat(a, &mut ah);
            self.hat(b, &mut bh);
            let mut ab = ws.take(n * n);
            let mut ba = ws.take(n * n);
            crate::linalg::matmul(&ah, &bh, &mut ab, n, n, n);
            crate::linalg::matmul(&bh, &ah, &mut ba, n, n, n);
            let mut k = 0;
            for i in 0..n {
                for j in i + 1..n {
                    out[k] = ab[i * n + j] - ba[i * n + j];
                    k += 1;
                }
            }
            ws.put(ba);
            ws.put(ab);
            ws.put(bh);
            ws.put(ah);
        });
    }

    fn exp_calls(&self) -> u64 {
        self.exps.get()
    }
    fn reset_exp_calls(&self) {
        self.exps.reset()
    }

    /// Great-circle distance.
    fn distance(&self, a: &[f64], b: &[f64]) -> f64 {
        let dot: f64 = a.iter().zip(b.iter()).map(|(x, y)| x * y).sum();
        dot.clamp(-1.0, 1.0).acos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stays_on_sphere() {
        let sp = Sphere::new(4);
        let mut y = vec![1.0, 0.0, 0.0, 0.0];
        let mut rng = crate::rng::Pcg64::new(1);
        for _ in 0..30 {
            let mut v = vec![0.0; sp.algebra_dim()];
            rng.fill_normal_scaled(0.4, &mut v);
            sp.exp_action(&v, &mut y);
        }
        assert!(sp.constraint_defect(&y) < 1e-11);
    }

    #[test]
    fn tangent_generator_moves_along_tangent() {
        // For a ⊥ y with ‖y‖=1: V y = a (first-order motion along a).
        let sp = Sphere::new(3);
        let y = vec![1.0, 0.0, 0.0];
        let a = vec![0.0, 1e-5, -2e-5];
        let mut v = vec![0.0; 3];
        sp.tangent_generator(&a, &y, &mut v);
        let mut y2 = y.clone();
        sp.exp_action(&v, &mut y2);
        for i in 0..3 {
            assert!((y2[i] - (y[i] + a[i])).abs() < 1e-9, "{i}");
        }
    }

    #[test]
    fn great_circle_distance() {
        let sp = Sphere::new(3);
        let a = vec![1.0, 0.0, 0.0];
        let b = vec![0.0, 1.0, 0.0];
        assert!((sp.distance(&a, &b) - std::f64::consts::FRAC_PI_2).abs() < 1e-12);
    }
}
