//! The n-torus 𝕋ⁿ (angles in (−π, π]) and its tangent bundle
//! T𝕋ᴺ ≅ 𝕋ᴺ × ℝᴺ — the state space of the stochastic Kuramoto experiment
//! (Section 4) and the Figure-1 memory benchmark (𝕋⁷).
//!
//! The group is the torus itself (abelian); exp is the identity on the
//! algebra ℝⁿ and the action is angle addition followed by wrapping. The
//! wrapped representation never leaves the manifold, which is exactly why a
//! Lie-group integrator is required: a Euclidean solver on lifted angles
//! drifts arbitrarily far from the fundamental domain and breaks the
//! periodic encodings downstream.

use super::{wrap_angle, ExpCounter, HomogeneousSpace};
use crate::memory::StepWorkspace;

/// 𝕋ⁿ with angle representation.
#[derive(Clone, Debug)]
pub struct Torus {
    n: usize,
    exps: ExpCounter,
}

impl Torus {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            exps: ExpCounter::default(),
        }
    }
}

impl HomogeneousSpace for Torus {
    fn point_dim(&self) -> usize {
        self.n
    }
    fn algebra_dim(&self) -> usize {
        self.n
    }

    fn exp_action(&self, v: &[f64], y: &mut [f64]) {
        self.exps.bump();
        for (yi, vi) in y.iter_mut().zip(v.iter()) {
            *yi = wrap_angle(*yi + vi);
        }
    }

    fn project(&self, y: &mut [f64]) {
        for yi in y.iter_mut() {
            *yi = wrap_angle(*yi);
        }
    }

    fn action_pullback(
        &self,
        _v: &[f64],
        _y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
    ) {
        // Wrapping is locally the identity chart.
        lam_y.copy_from_slice(lam_out);
        lam_v.copy_from_slice(lam_out);
    }

    /// Angle addition + wrap is elementwise: one pass over the lane-major
    /// block, per-lane op order identical to scalar.
    fn exp_action_lanes(&self, v: &[f64], y: &mut [f64], lanes: usize, _ws: &mut StepWorkspace) {
        self.exps.bump_many(lanes as u64);
        for (yi, vi) in y.iter_mut().zip(v.iter()) {
            *yi = wrap_angle(*yi + vi);
        }
    }

    fn action_pullback_lanes(
        &self,
        _v: &[f64],
        _y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
        _lanes: usize,
        _ws: &mut StepWorkspace,
    ) {
        lam_y.copy_from_slice(lam_out);
        lam_v.copy_from_slice(lam_out);
    }

    fn exp_calls(&self) -> u64 {
        self.exps.get()
    }
    fn reset_exp_calls(&self) {
        self.exps.reset()
    }

    fn distance(&self, a: &[f64], b: &[f64]) -> f64 {
        a.iter()
            .zip(b.iter())
            .map(|(x, y)| {
                let d = wrap_angle(x - y);
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }
}

/// T𝕋ᴺ = 𝕋ᴺ × ℝᴺ: first `n` coordinates are angles θ, last `n` are
/// velocities ω. Points are `[θ; ω]`, algebra elements `[dθ; dω]`.
#[derive(Clone, Debug)]
pub struct TTorus {
    n: usize,
    exps: ExpCounter,
}

impl TTorus {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            exps: ExpCounter::default(),
        }
    }
    /// Number of oscillators N (point dim is 2N).
    pub fn oscillators(&self) -> usize {
        self.n
    }
}

impl HomogeneousSpace for TTorus {
    fn point_dim(&self) -> usize {
        2 * self.n
    }
    fn algebra_dim(&self) -> usize {
        2 * self.n
    }

    fn exp_action(&self, v: &[f64], y: &mut [f64]) {
        self.exps.bump();
        for i in 0..self.n {
            y[i] = wrap_angle(y[i] + v[i]);
        }
        for i in self.n..2 * self.n {
            y[i] += v[i];
        }
    }

    fn project(&self, y: &mut [f64]) {
        for yi in y.iter_mut().take(self.n) {
            *yi = wrap_angle(*yi);
        }
    }

    fn action_pullback(
        &self,
        _v: &[f64],
        _y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
    ) {
        lam_y.copy_from_slice(lam_out);
        lam_v.copy_from_slice(lam_out);
    }

    /// Lane-major split: angle components occupy the first `n·lanes` block
    /// entries, velocities the last `n·lanes` — wrap the former, add the
    /// latter, per-lane op order identical to scalar.
    fn exp_action_lanes(&self, v: &[f64], y: &mut [f64], lanes: usize, _ws: &mut StepWorkspace) {
        self.exps.bump_many(lanes as u64);
        let split = self.n * lanes;
        for i in 0..split {
            y[i] = wrap_angle(y[i] + v[i]);
        }
        for i in split..2 * split {
            y[i] += v[i];
        }
    }

    fn action_pullback_lanes(
        &self,
        _v: &[f64],
        _y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
        _lanes: usize,
        _ws: &mut StepWorkspace,
    ) {
        lam_y.copy_from_slice(lam_out);
        lam_v.copy_from_slice(lam_out);
    }

    fn exp_calls(&self) -> u64 {
        self.exps.get()
    }
    fn reset_exp_calls(&self) {
        self.exps.reset()
    }

    fn distance(&self, a: &[f64], b: &[f64]) -> f64 {
        let mut s = 0.0;
        for i in 0..self.n {
            let d = wrap_angle(a[i] - b[i]);
            s += d * d;
        }
        for i in self.n..2 * self.n {
            let d = a[i] - b[i];
            s += d * d;
        }
        s.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn torus_wraps() {
        let t = Torus::new(2);
        let mut y = vec![3.0, -3.0];
        t.exp_action(&[0.5, -0.5], &mut y);
        assert!(y[0] > -std::f64::consts::PI && y[0] <= std::f64::consts::PI);
        // 3.5 wraps to 3.5 - 2π ≈ -2.783.
        assert!((y[0] - (3.5 - 2.0 * std::f64::consts::PI)).abs() < 1e-12);
        assert!((y[1] - (-3.5 + 2.0 * std::f64::consts::PI)).abs() < 1e-12);
    }

    #[test]
    fn ttorus_splits_wrap() {
        let t = TTorus::new(1);
        let mut y = vec![3.0, 3.0];
        t.exp_action(&[0.5, 0.5], &mut y);
        assert!((y[0] - (3.5 - 2.0 * std::f64::consts::PI)).abs() < 1e-12); // wrapped
        assert!((y[1] - 3.5).abs() < 1e-12); // not wrapped
    }

    #[test]
    fn wrapped_distance_shorter_way_round() {
        let t = Torus::new(1);
        let a = [std::f64::consts::PI - 0.1];
        let b = [-std::f64::consts::PI + 0.1];
        assert!((t.distance(&a, &b) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn exp_counter_counts() {
        let t = Torus::new(1);
        let mut y = vec![0.0];
        for _ in 0..5 {
            t.exp_action(&[0.1], &mut y);
        }
        assert_eq!(t.exp_calls(), 5);
        t.reset_exp_calls();
        assert_eq!(t.exp_calls(), 0);
    }
}
