//! Flat space ℝⁿ as a (degenerate) homogeneous space: the group is the
//! translation group, exp is the identity and the action is vector addition.
//! On this space every CF integrator collapses to its classical Euclidean
//! form — the paper's "flat manifold collapse" sanity condition, which the
//! tests of `solvers::cfees` exercise.

use super::{ExpCounter, HomogeneousSpace};
use crate::memory::StepWorkspace;

#[derive(Clone, Debug)]
pub struct Euclidean {
    n: usize,
    exps: ExpCounter,
}

impl Euclidean {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            exps: ExpCounter::default(),
        }
    }
}

impl HomogeneousSpace for Euclidean {
    fn point_dim(&self) -> usize {
        self.n
    }
    fn algebra_dim(&self) -> usize {
        self.n
    }

    fn exp_action(&self, v: &[f64], y: &mut [f64]) {
        self.exps.bump();
        for (yi, vi) in y.iter_mut().zip(v.iter()) {
            *yi += vi;
        }
    }

    fn action_pullback(
        &self,
        _v: &[f64],
        _y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
    ) {
        lam_y.copy_from_slice(lam_out);
        lam_v.copy_from_slice(lam_out);
    }

    /// Lane block: translation is elementwise, so the whole lane-major
    /// block advances in one pass — per-lane op order identical to scalar.
    fn exp_action_lanes(&self, v: &[f64], y: &mut [f64], lanes: usize, _ws: &mut StepWorkspace) {
        self.exps.bump_many(lanes as u64);
        for (yi, vi) in y.iter_mut().zip(v.iter()) {
            *yi += vi;
        }
    }

    fn action_pullback_lanes(
        &self,
        _v: &[f64],
        _y: &[f64],
        lam_out: &[f64],
        lam_y: &mut [f64],
        lam_v: &mut [f64],
        _lanes: usize,
        _ws: &mut StepWorkspace,
    ) {
        lam_y.copy_from_slice(lam_out);
        lam_v.copy_from_slice(lam_out);
    }

    fn exp_calls(&self) -> u64 {
        self.exps.get()
    }
    fn reset_exp_calls(&self) {
        self.exps.reset()
    }
}
