//! Batch (distribution-matching) training losses.
//!
//! Neural SDE training is a distribution-matching problem: a batch of
//! generated trajectories is compared against data. A [`BatchLoss`] sees the
//! whole generated batch at the observation times and returns the loss plus
//! the cotangent of every observed state — the entry point of the backward
//! sweep run per-sample by the coordinator.
//!
//! Implementations: [`MomentMatch`] (OU/GBM MSE against exact-moment
//! targets), [`EnergyScore`] (Kuramoto; wrapped-on-θ distance, Gneiting–
//! Raftery strictly proper score), [`SigMmd`] (stochastic-volatility
//! benchmarks; truncated time-augmented signature MMD²).

use crate::sig;

/// Batch loss over observed states `(batch, n_obs, dim)` flattened.
pub trait BatchLoss: Send + Sync {
    /// Returns (loss, cotangents with the same layout as `obs`).
    fn eval_grad(&self, obs: &[f64], batch: usize, n_obs: usize, dim: usize) -> (f64, Vec<f64>);
}

/// Per-timepoint first/second moment matching (the paper's OU/GBM "MSE
/// against the true dynamics" objective on 50k exact samples):
/// L = Σ_t Σ_d (mean − m̂)² + (m2 − m̂2)².
pub struct MomentMatch {
    /// Targets: (n_obs, dim) means and second moments from exact data.
    pub target_mean: Vec<f64>,
    pub target_m2: Vec<f64>,
}

impl MomentMatch {
    /// Build from a data batch shaped like the generated observations.
    pub fn from_data(data: &[f64], batch: usize, n_obs: usize, dim: usize) -> Self {
        let mut mean = vec![0.0; n_obs * dim];
        let mut m2 = vec![0.0; n_obs * dim];
        for b in 0..batch {
            for k in 0..n_obs * dim {
                let v = data[b * n_obs * dim + k];
                mean[k] += v / batch as f64;
                m2[k] += v * v / batch as f64;
            }
        }
        Self {
            target_mean: mean,
            target_m2: m2,
        }
    }
}

impl BatchLoss for MomentMatch {
    fn eval_grad(&self, obs: &[f64], batch: usize, n_obs: usize, dim: usize) -> (f64, Vec<f64>) {
        let k_tot = n_obs * dim;
        let bf = batch as f64;
        let mut mean = vec![0.0; k_tot];
        let mut m2 = vec![0.0; k_tot];
        for b in 0..batch {
            for k in 0..k_tot {
                let v = obs[b * k_tot + k];
                mean[k] += v / bf;
                m2[k] += v * v / bf;
            }
        }
        let mut loss = 0.0;
        let mut dmean = vec![0.0; k_tot];
        let mut dm2 = vec![0.0; k_tot];
        for k in 0..k_tot {
            let e1 = mean[k] - self.target_mean[k];
            let e2 = m2[k] - self.target_m2[k];
            loss += (e1 * e1 + e2 * e2) / k_tot as f64;
            dmean[k] = 2.0 * e1 / k_tot as f64;
            dm2[k] = 2.0 * e2 / k_tot as f64;
        }
        let mut grad = vec![0.0; obs.len()];
        for b in 0..batch {
            for k in 0..k_tot {
                let v = obs[b * k_tot + k];
                grad[b * k_tot + k] = dmean[k] / bf + dm2[k] * 2.0 * v / bf;
            }
        }
        (loss, grad)
    }
}

/// Energy score against a data sample, with optionally wrapped coordinates
/// (the Kuramoto loss: wrap the first `wrap_dims` state coordinates on 𝕋):
/// ES = (2/BJ) ΣΣ d(X_b, Y_j) − (1/B²) ΣΣ d(X_b, X_b').
pub struct EnergyScore {
    /// Data observations `(J, n_obs, dim)` flattened.
    pub data: Vec<f64>,
    pub data_count: usize,
    /// Number of leading coordinates to wrap to (−π, π] per state.
    pub wrap_dims: usize,
}

impl EnergyScore {
    fn dist_grad(
        &self,
        a: &[f64],
        b: &[f64],
        dim: usize,
        grad_a: Option<&mut [f64]>,
        scale: f64,
    ) -> f64 {
        // d = Σ_obs Σ_k |wrap(a − b)| (L1, as in Appendix I.5).
        let mut total = 0.0;
        let mut g: Vec<f64> = Vec::new();
        let want_grad = grad_a.is_some();
        if want_grad {
            g = vec![0.0; a.len()];
        }
        for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            let kd = k % dim;
            let mut d = x - y;
            if kd < self.wrap_dims {
                d = crate::lie::wrap_angle(d);
            }
            total += d.abs();
            if want_grad {
                g[k] = d.signum();
            }
        }
        if let Some(ga) = grad_a {
            for (o, v) in ga.iter_mut().zip(g.iter()) {
                *o += scale * v;
            }
        }
        total
    }
}

impl BatchLoss for EnergyScore {
    fn eval_grad(&self, obs: &[f64], batch: usize, n_obs: usize, dim: usize) -> (f64, Vec<f64>) {
        let k_tot = n_obs * dim;
        let jn = self.data_count;
        let mut grad = vec![0.0; obs.len()];
        let mut loss = 0.0;
        // Cross term.
        let c1 = 2.0 / (batch * jn) as f64;
        for b in 0..batch {
            for j in 0..jn {
                let d = self.dist_grad(
                    &obs[b * k_tot..(b + 1) * k_tot],
                    &self.data[j * k_tot..(j + 1) * k_tot],
                    dim,
                    Some(&mut grad[b * k_tot..(b + 1) * k_tot]),
                    c1,
                );
                loss += c1 * d;
            }
        }
        // Self term (subtract).
        let c2 = 1.0 / (batch * batch) as f64;
        for b in 0..batch {
            for b2 in 0..batch {
                if b == b2 {
                    continue;
                }
                let d = self.dist_grad(
                    &obs[b * k_tot..(b + 1) * k_tot],
                    &obs[b2 * k_tot..(b2 + 1) * k_tot],
                    dim,
                    Some(&mut grad[b * k_tot..(b + 1) * k_tot]),
                    -2.0 * c2, // both (b,b2) and (b2,b) gradients land on b
                );
                loss -= c2 * d;
            }
        }
        (loss, grad)
    }
}

/// Truncated time-augmented signature MMD² against data paths (the paper's
/// stochastic-volatility objective). Gradients flow to the generated path
/// values through the signature VJP.
pub struct SigMmd {
    /// Data signature features, one per data path.
    pub data_sigs: Vec<Vec<f64>>,
    pub depth: usize,
    pub dt: f64,
}

impl SigMmd {
    pub fn from_data(data: &[f64], count: usize, n_obs: usize, dim: usize, depth: usize, dt: f64) -> Self {
        let k_tot = n_obs * dim;
        let data_sigs = (0..count)
            .map(|j| {
                sig::signature_time_augmented(&data[j * k_tot..(j + 1) * k_tot], n_obs, dim, dt, depth)
            })
            .collect();
        Self {
            data_sigs,
            depth,
            dt,
        }
    }
}

impl BatchLoss for SigMmd {
    fn eval_grad(&self, obs: &[f64], batch: usize, n_obs: usize, dim: usize) -> (f64, Vec<f64>) {
        let k_tot = n_obs * dim;
        let xs: Vec<Vec<f64>> = (0..batch)
            .map(|b| {
                sig::signature_time_augmented(
                    &obs[b * k_tot..(b + 1) * k_tot],
                    n_obs,
                    dim,
                    self.dt,
                    self.depth,
                )
            })
            .collect();
        let loss = sig::mmd2_linear_biased(&xs, &self.data_sigs);
        let feat_cot = sig::mmd2_feature_cotangent(&xs, &self.data_sigs);
        let mut grad = vec![0.0; obs.len()];
        for b in 0..batch {
            // Time-augmented path: rebuild and take VJP w.r.t. value channels.
            let vals = &obs[b * k_tot..(b + 1) * k_tot];
            let mut aug = vec![0.0; n_obs * (dim + 1)];
            for i in 0..n_obs {
                aug[i * (dim + 1)] = i as f64 * self.dt;
                aug[i * (dim + 1) + 1..(i + 1) * (dim + 1)]
                    .copy_from_slice(&vals[i * dim..(i + 1) * dim]);
            }
            let g_aug = sig::signature_vjp_fd(&aug, n_obs, dim + 1, self.depth, &feat_cot);
            for i in 0..n_obs {
                for d in 0..dim {
                    grad[b * k_tot + i * dim + d] = g_aug[i * (dim + 1) + 1 + d];
                }
            }
        }
        (loss, grad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn fd_check(loss: &dyn BatchLoss, obs: &[f64], batch: usize, n_obs: usize, dim: usize, tol: f64) {
        let (_, grad) = loss.eval_grad(obs, batch, n_obs, dim);
        let eps = 1e-6;
        let mut rng = Pcg64::new(99);
        for _ in 0..10 {
            let k = rng.below(obs.len());
            let mut op = obs.to_vec();
            op[k] += eps;
            let mut om = obs.to_vec();
            om[k] -= eps;
            let (lp, _) = loss.eval_grad(&op, batch, n_obs, dim);
            let (lm, _) = loss.eval_grad(&om, batch, n_obs, dim);
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - grad[k]).abs() < tol, "k={k}: {fd} vs {}", grad[k]);
        }
    }

    #[test]
    fn moment_match_zero_at_target() {
        let mut rng = Pcg64::new(1);
        let (batch, n_obs, dim) = (8, 3, 2);
        let mut data = vec![0.0; batch * n_obs * dim];
        rng.fill_normal(&mut data);
        let loss = MomentMatch::from_data(&data, batch, n_obs, dim);
        let (l, _) = loss.eval_grad(&data, batch, n_obs, dim);
        assert!(l < 1e-20, "loss at target {l}");
    }

    #[test]
    fn moment_match_grad_fd() {
        let mut rng = Pcg64::new(2);
        let (batch, n_obs, dim) = (4, 3, 2);
        let mut data = vec![0.0; batch * n_obs * dim];
        rng.fill_normal(&mut data);
        let loss = MomentMatch::from_data(&data, batch, n_obs, dim);
        let mut obs = vec![0.0; batch * n_obs * dim];
        rng.fill_normal(&mut obs);
        fd_check(&loss, &obs, batch, n_obs, dim, 1e-6);
    }

    #[test]
    fn energy_score_grad_fd() {
        let mut rng = Pcg64::new(3);
        let (batch, n_obs, dim) = (4, 2, 3);
        let mut data = vec![0.0; 5 * n_obs * dim];
        rng.fill_normal(&mut data);
        let loss = EnergyScore {
            data,
            data_count: 5,
            wrap_dims: 1,
        };
        let mut obs = vec![0.0; batch * n_obs * dim];
        rng.fill_normal(&mut obs);
        fd_check(&loss, &obs, batch, n_obs, dim, 1e-5);
    }

    #[test]
    fn energy_score_zero_mean_property() {
        // ES is a strictly proper score: matching the data distribution
        // yields a lower score than a shifted one.
        let mut rng = Pcg64::new(5);
        let (n, n_obs, dim) = (64, 1, 1);
        let data: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let loss = EnergyScore {
            data: data.clone(),
            data_count: n,
            wrap_dims: 0,
        };
        let good: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let bad: Vec<f64> = (0..n).map(|_| rng.normal() + 3.0).collect();
        let (lg, _) = loss.eval_grad(&good, n, n_obs, dim);
        let (lb, _) = loss.eval_grad(&bad, n, n_obs, dim);
        assert!(lg < lb, "good {lg} must beat shifted {lb}");
    }

    #[test]
    fn sig_mmd_grad_fd() {
        let mut rng = Pcg64::new(7);
        let (batch, n_obs, dim) = (3, 4, 1);
        let mut data = vec![0.0; 4 * n_obs * dim];
        rng.fill_normal(&mut data);
        let loss = SigMmd::from_data(&data, 4, n_obs, dim, 2, 0.25);
        let mut obs = vec![0.0; batch * n_obs * dim];
        rng.fill_normal(&mut obs);
        fd_check(&loss, &obs, batch, n_obs, dim, 1e-5);
    }

    #[test]
    fn sig_mmd_discriminates_distributions() {
        let mut rng = Pcg64::new(9);
        let (n_obs, dim) = (8, 1);
        let mk = |scale: f64, rng: &mut Pcg64| -> Vec<f64> {
            // Random-walk paths with step scale.
            let mut v = vec![0.0; 16 * n_obs];
            for b in 0..16 {
                let mut acc = 0.0;
                for i in 0..n_obs {
                    acc += scale * rng.normal();
                    v[b * n_obs + i] = acc;
                }
            }
            v
        };
        let data = mk(0.3, &mut rng);
        let loss = SigMmd::from_data(&data, 16, n_obs, dim, 3, 0.125);
        let same = mk(0.3, &mut rng);
        let diff = mk(1.5, &mut rng);
        let (ls, _) = loss.eval_grad(&same, 16, n_obs, dim);
        let (ld, _) = loss.eval_grad(&diff, 16, n_obs, dim);
        assert!(ls < ld, "matched {ls} must beat mismatched {ld}");
    }
}
