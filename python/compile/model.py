"""Layer-2 JAX model: neural SDE forward/backward built on the L1 kernels.

The model mirrors the Rust-native NSDE (rust/src/nn/neural_sde.rs): MLP
drift + softplus-scaled diagonal MLP diffusion, advanced by the EES(2,5)
Williamson 2N step whose register update is the Pallas kernel
``fused_2n_update``. The full solve is a single ``lax.scan`` so the whole
trajectory lowers into one HLO while-loop; ``loss_and_grad`` differentiates
it end-to-end (discretise-then-optimise inside XLA).

Everything here is build-time only: ``aot.py`` lowers these functions to
HLO text once, and the Rust coordinator executes the artifacts.
"""

import jax
import jax.numpy as jnp

from .kernels.ees_step import EES25_A, EES25_B, fused_2n_update


def init_mlp(key, sizes):
    """He-initialised MLP parameter pytree."""
    params = []
    for fan_in, fan_out in zip(sizes[:-1], sizes[1:]):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (fan_out, fan_in)) * jnp.sqrt(2.0 / fan_in)
        params.append((w, jnp.zeros((fan_out,))))
    return params


def mlp_apply(params, x, final_softplus=False, out_scale=1.0):
    """LipSwish MLP (matches the Rust implementation)."""
    for i, (w, b) in enumerate(params):
        x = x @ w.T + b
        if i + 1 < len(params):
            x = 0.909 * x * jax.nn.sigmoid(x)
        elif final_softplus:
            x = jax.nn.softplus(x)
    return x * out_scale


def init_nsde(key, dim, width=32, depth=2):
    k1, k2 = jax.random.split(key)
    drift_sizes = [dim] + [width] * depth + [dim]
    diff_sizes = [dim] + [width] * depth + [dim]
    return {
        "drift": init_mlp(k1, drift_sizes),
        "diffusion": init_mlp(k2, diff_sizes),
    }


def combined_increment(params, y, h, dw):
    """Simplified-RK combined increment F(y; h, dW) = f(y)h + sigma(y)*dW."""
    f = mlp_apply(params["drift"], y)
    sigma = mlp_apply(params["diffusion"], y, final_softplus=True, out_scale=0.2)
    return f * h + sigma * dw


def nsde_ees25_step(params, y, dw, h, *, interpret=True, use_pallas=True):
    """One EES(2,5) 2N step of the neural SDE over a batch.

    The MLP evaluations stay at L2 (XLA-fused matmuls); the two-register
    recurrence goes through the Pallas kernel.
    """
    delta = jnp.zeros_like(y)
    for a_l, b_l in zip(EES25_A, EES25_B):
        k = combined_increment(params, y, h, dw)
        if use_pallas:
            delta, y = fused_2n_update(delta, k, y, a_l, b_l, interpret=interpret)
        else:
            delta = a_l * delta + k
            y = y + b_l * delta
    return y


def nsde_solve(params, y0, dws, h, *, use_pallas=True):
    """Integrate over all steps with lax.scan; returns the final state.

    dws: (steps, batch, dim).
    """

    def body(y, dw):
        return nsde_ees25_step(params, y, dw, h, use_pallas=use_pallas), None

    y_final, _ = jax.lax.scan(body, y0, dws)
    return y_final


def moment_loss(params, y0, dws, h, target_mean, target_m2, *, use_pallas=True):
    """Terminal moment-matching loss (the OU/GBM objective)."""
    y = nsde_solve(params, y0, dws, h, use_pallas=use_pallas)
    mean = jnp.mean(y, axis=0)
    m2 = jnp.mean(y * y, axis=0)
    return jnp.mean((mean - target_mean) ** 2 + (m2 - target_m2) ** 2)


def loss_and_grad(params, y0, dws, h, target_mean, target_m2, *, use_pallas=True):
    """(loss, flat gradient list) — the artifact the Rust trainer executes."""
    loss, grads = jax.value_and_grad(moment_loss)(
        params, y0, dws, h, target_mean, target_m2, use_pallas=use_pallas
    )
    flat, _ = jax.tree_util.tree_flatten(grads)
    return (loss, *flat)


def param_leaves(params):
    """Flatten the parameter pytree (fixed order used by the artifacts)."""
    flat, treedef = jax.tree_util.tree_flatten(params)
    return flat, treedef


def loss_and_grad_flat(flat_params, treedef, y0, dws, h, target_mean, target_m2):
    """Training step over *flat* parameter inputs so the AOT artifact takes
    the parameters as runtime buffers (the Rust optimiser owns them)."""
    params = jax.tree_util.tree_unflatten(treedef, flat_params)
    return loss_and_grad(
        params, y0, dws, h, target_mean, target_m2, use_pallas=False
    )
