"""AOT export: lower the L2/L1 functions to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that the
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all f32, fixed example shapes — one compiled executable per model
variant, as the runtime expects):

- ``ees_step.hlo.txt``      — fused OU EES(2,5) step, batch 8 x dim 4
                              (Pallas kernel, interpret=True lowering);
- ``nsde_step.hlo.txt``     — one neural-SDE EES(2,5) step, batch 8 x dim 4;
- ``nsde_train_step.hlo.txt`` — loss + parameter gradients through a
                              16-step scan (discretise-then-optimise).

Usage: ``python -m compile.aot --out-dir ../artifacts`` (see Makefile).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as m
from .kernels.ees_step import ou_ees25_step


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(fn, args, path):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--dim", type=int, default=4)
    ap.add_argument("--steps", type=int, default=16)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    b, d, steps = args.batch, args.dim, args.steps

    f32 = jnp.float32
    y = jax.ShapeDtypeStruct((b, d), f32)
    dw = jax.ShapeDtypeStruct((b, d), f32)
    h = jax.ShapeDtypeStruct((), f32)

    # 1. Fused OU EES(2,5) Pallas step.
    export(
        lambda y, dw, h: (ou_ees25_step(y, dw, h),),
        (y, dw, h),
        os.path.join(args.out_dir, "ees_step.hlo.txt"),
    )

    # 2/3. Neural SDE step and training step with concrete init params.
    params = m.init_nsde(jax.random.PRNGKey(0), d, width=16, depth=2)
    params = jax.tree_util.tree_map(lambda x: x.astype(f32), params)

    export(
        lambda y, dw, h: (m.nsde_ees25_step(params, y, dw, h),),
        (y, dw, h),
        os.path.join(args.out_dir, "nsde_step.hlo.txt"),
    )

    # Reverse-mode autodiff through an interpret-mode pallas_call is not
    # supported by jax; the training artifact differentiates the identical
    # pure-jnp register update instead (bitwise-equal numerics — asserted by
    # python/tests/test_model.py::test_step_pallas_equals_jnp_path).
    # Parameters are runtime *inputs* (flat leaves, fixed order) so the Rust
    # optimiser owns them across steps.
    flat, treedef = m.param_leaves(params)
    leaf_specs = [jax.ShapeDtypeStruct(x.shape, f32) for x in flat]
    dws = jax.ShapeDtypeStruct((steps, b, d), f32)
    tgt = jax.ShapeDtypeStruct((d,), f32)
    export(
        lambda *inputs: m.loss_and_grad_flat(
            list(inputs[: len(flat)]),
            treedef,
            jnp.zeros((b, d), f32),
            inputs[len(flat)],
            inputs[len(flat) + 1],
            inputs[len(flat) + 2],
            inputs[len(flat) + 3],
        ),
        (*leaf_specs, dws, h, tgt, tgt),
        os.path.join(args.out_dir, "nsde_train_step.hlo.txt"),
    )
    # Record the artifact's parameter layout for the Rust side.
    with open(os.path.join(args.out_dir, "nsde_train_step.meta"), "w") as f:
        f.write(f"batch = {b}\ndim = {d}\nsteps = {steps}\n")
        f.write(f"n_leaves = {len(flat)}\n")
        for i, x in enumerate(flat):
            f.write(f"leaf{i} = {list(x.shape)}\n")


if __name__ == "__main__":
    main()
