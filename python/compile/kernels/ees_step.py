"""Layer-1 Pallas kernels: the Williamson 2N EES hot path.

Two kernels:

- ``fused_2n_update``: the fused two-register stage update
  ``delta' = A*delta + k;  y' = y + B*delta'`` over a batch — the inner
  operation of every 2N/CF-EES stage. Fusing it avoids materialising the
  intermediate ``A*delta + k`` in HBM (one read+write per operand instead of
  two round trips).

- ``ou_ees25_step``: a complete EES(2,5;1/10) step for the OU-family SDE
  ``dy = nu*(mu - y) dt + sigma dW`` computed entirely inside one kernel —
  three stage evaluations and the 2N recurrence fused over the batch tile.

TPU notes (DESIGN.md §Hardware-Adaptation): both kernels are elementwise
over (batch, dim) and tile the batch dimension through VMEM via BlockSpec;
``interpret=True`` is mandatory on CPU-PJRT (real TPU lowering emits a
Mosaic custom-call the CPU plugin cannot execute). The MXU-facing matmuls of
the neural drift live at Layer 2 (model.py) so XLA can fuse them with these
elementwise kernels.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# EES(2,5; x=1/10) Williamson 2N coefficients (paper Appendix D).
EES25_A = (0.0, -7.0 / 15.0, -35.0 / 32.0)
EES25_B = (1.0 / 3.0, 15.0 / 16.0, 2.0 / 5.0)
# Stage abscissae c_l for time offsets.
EES25_C = (0.0, 1.0 / 3.0, 5.0 / 6.0)

DEFAULT_BLOCK = 128


def _fused_2n_kernel(delta_ref, k_ref, y_ref, dout_ref, yout_ref, *, a, b):
    delta = a * delta_ref[...] + k_ref[...]
    dout_ref[...] = delta
    yout_ref[...] = y_ref[...] + b * delta


def fused_2n_update(delta, k, y, a, b, *, block=DEFAULT_BLOCK, interpret=True):
    """One 2N stage update: returns (delta', y').

    delta, k, y: (batch, dim) arrays; a, b: python floats (A_l, B_l).
    """
    batch, dim = y.shape
    grid = (pl.cdiv(batch, block),)
    spec = pl.BlockSpec((block, dim), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct(y.shape, y.dtype),
        jax.ShapeDtypeStruct(y.shape, y.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_fused_2n_kernel, a=float(a), b=float(b)),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(delta, k, y)


def _ou_step_kernel(y_ref, dw_ref, h_ref, out_ref, *, nu, mu, sigma):
    y = y_ref[...]
    h = h_ref[0]
    dw = dw_ref[...]
    delta = jnp.zeros_like(y)
    for a_l, b_l in zip(EES25_A, EES25_B):
        k = nu * (mu - y) * h + sigma * dw
        delta = a_l * delta + k
        y = y + b_l * delta
    out_ref[...] = y


def ou_ees25_step(y, dw, h, *, nu=0.2, mu=0.1, sigma=2.0, block=DEFAULT_BLOCK, interpret=True):
    """Full EES(2,5) step of the OU SDE, fused in one kernel.

    y, dw: (batch, dim); h: scalar array shape ().
    """
    batch, dim = y.shape
    grid = (pl.cdiv(batch, block),)
    spec = pl.BlockSpec((block, dim), lambda i: (i, 0))
    h_spec = pl.BlockSpec(memory_space=pl.ANY) if False else None  # h passed whole
    return pl.pallas_call(
        functools.partial(
            _ou_step_kernel, nu=float(nu), mu=float(mu), sigma=float(sigma)
        ),
        grid=grid,
        in_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(y.shape, y.dtype),
        interpret=interpret,
    )(y, dw, jnp.reshape(h, (1,)))


def vmem_footprint_bytes(block, dim, dtype_bytes=4, n_buffers=5):
    """Estimated VMEM bytes for one grid step of fused_2n_update
    (3 inputs + 2 outputs double-buffered is n_buffers*2)."""
    return block * dim * dtype_bytes * n_buffers * 2
