"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in :mod:`compile.kernels.ees_step` has a reference here written
with plain jax.numpy; pytest asserts allclose across shapes and dtypes
(hypothesis sweeps the shape/dtype space).
"""

import jax.numpy as jnp

from .ees_step import EES25_A, EES25_B


def fused_2n_update_ref(delta, k, y, a, b):
    delta = a * delta + k
    return delta, y + b * delta


def ou_ees25_step_ref(y, dw, h, *, nu=0.2, mu=0.1, sigma=2.0):
    delta = jnp.zeros_like(y)
    for a_l, b_l in zip(EES25_A, EES25_B):
        kk = nu * (mu - y) * h + sigma * dw
        delta = a_l * delta + kk
        y = y + b_l * delta
    return y


def ees25_step_generic_ref(f, y, dw, h):
    """Generic EES(2,5) 2N step for a combined-increment function
    f(y, h, dw) -> increment (the simplified-RK evaluation of eq. 7)."""
    delta = jnp.zeros_like(y)
    for a_l, b_l in zip(EES25_A, EES25_B):
        k = f(y, h, dw)
        delta = a_l * delta + k
        y = y + b_l * delta
    return y
