"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; numerics must match the references to
float tolerance. These tests are the build-time gate before `make
artifacts` output is trusted by the Rust runtime.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ees_step import (
    EES25_A,
    EES25_B,
    fused_2n_update,
    ou_ees25_step,
    vmem_footprint_bytes,
)

jax.config.update("jax_enable_x64", True)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=dtype)


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(min_value=1, max_value=300),
    dim=st.integers(min_value=1, max_value=9),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
    stage=st.integers(min_value=0, max_value=2),
)
def test_fused_2n_update_matches_ref(batch, dim, dtype, stage):
    key = jax.random.PRNGKey(batch * 31 + dim)
    k1, k2, k3 = jax.random.split(key, 3)
    delta = rand(k1, (batch, dim), dtype)
    k = rand(k2, (batch, dim), dtype)
    y = rand(k3, (batch, dim), dtype)
    a, b = EES25_A[stage], EES25_B[stage]
    d_ref, y_ref = ref.fused_2n_update_ref(delta, k, y, a, b)
    d_out, y_out = fused_2n_update(delta, k, y, a, b)
    tol = 1e-6 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(d_out, d_ref, rtol=tol, atol=tol)
    np.testing.assert_allclose(y_out, y_ref, rtol=tol, atol=tol)


@settings(max_examples=20, deadline=None)
@given(
    batch=st.integers(min_value=1, max_value=200),
    dim=st.integers(min_value=1, max_value=8),
    h=st.floats(min_value=1e-4, max_value=0.5),
)
def test_ou_step_matches_ref(batch, dim, h):
    key = jax.random.PRNGKey(batch * 7 + dim)
    k1, k2 = jax.random.split(key)
    y = rand(k1, (batch, dim), jnp.float64)
    dw = rand(k2, (batch, dim), jnp.float64) * np.sqrt(h)
    got = ou_ees25_step(y, dw, jnp.asarray(h))
    want = ref.ou_ees25_step_ref(y, dw, h)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_ou_step_near_reversible():
    """Effective symmetry survives the kernel path: stepping back with
    negated increments recovers the state to O(h^6)."""
    key = jax.random.PRNGKey(3)
    y0 = rand(key, (16, 3), jnp.float64)
    h = 0.05
    dw = jnp.zeros_like(y0)
    y1 = ou_ees25_step(y0, dw, jnp.asarray(h))
    y2 = ou_ees25_step(y1, -dw, jnp.asarray(-h))
    np.testing.assert_allclose(y2, y0, rtol=0, atol=1e-9)


def test_block_boundary_batches():
    """Batch sizes straddling the BlockSpec tile must agree with the ref."""
    for batch in (127, 128, 129, 257):
        key = jax.random.PRNGKey(batch)
        y = rand(key, (batch, 4), jnp.float32)
        dw = jnp.zeros_like(y)
        got = ou_ees25_step(y, dw, jnp.asarray(0.1, jnp.float32))
        want = ref.ou_ees25_step_ref(y, dw, 0.1)
        np.testing.assert_allclose(got, want, rtol=2e-6, atol=2e-6)


def test_vmem_footprint_within_budget():
    """Structural TPU check: the default tile fits comfortably in 16 MiB of
    VMEM (the optimisation target recorded in DESIGN.md)."""
    assert vmem_footprint_bytes(128, 1024) < 16 * 2**20


@pytest.mark.parametrize("stage", [0, 1, 2])
def test_coefficients_match_paper(stage):
    """Williamson coefficients equal the closed forms of Appendix D."""
    want_a = (0.0, -7.0 / 15.0, -35.0 / 32.0)
    want_b = (1.0 / 3.0, 15.0 / 16.0, 2.0 / 5.0)
    assert EES25_A[stage] == pytest.approx(want_a[stage], abs=0)
    assert EES25_B[stage] == pytest.approx(want_b[stage], abs=0)
