"""L2 correctness: the JAX NSDE model — kernel path vs pure-jnp path,
shapes, gradient flow, and the flat-collapse identity with the tableau.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as m
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def make_params(dim=3, width=8, depth=2, seed=0):
    return m.init_nsde(jax.random.PRNGKey(seed), dim, width=width, depth=depth)


def test_step_pallas_equals_jnp_path():
    params = make_params()
    key = jax.random.PRNGKey(1)
    y = jax.random.normal(key, (12, 3))
    dw = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (12, 3))
    h = jnp.asarray(0.05)
    a = m.nsde_ees25_step(params, y, dw, h, use_pallas=True)
    b = m.nsde_ees25_step(params, y, dw, h, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12)


def test_step_matches_generic_reference():
    params = make_params()
    y = jax.random.normal(jax.random.PRNGKey(3), (5, 3))
    dw = 0.2 * jax.random.normal(jax.random.PRNGKey(4), (5, 3))
    h = jnp.asarray(0.1)
    got = m.nsde_ees25_step(params, y, dw, h)
    want = ref.ees25_step_generic_ref(
        lambda y, h, dw: m.combined_increment(params, y, h, dw), y, dw, h
    )
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_solve_shapes_and_scan():
    params = make_params()
    steps, batch, dim = 7, 4, 3
    y0 = jnp.zeros((batch, dim))
    dws = 0.1 * jax.random.normal(jax.random.PRNGKey(5), (steps, batch, dim))
    y = m.nsde_solve(params, y0, dws, jnp.asarray(0.05))
    assert y.shape == (batch, dim)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_gradients_flow_and_match_fd():
    params = make_params(dim=2, width=4, depth=1, seed=7)
    steps, batch, dim = 3, 6, 2
    y0 = jnp.zeros((batch, dim))
    dws = 0.3 * jax.random.normal(jax.random.PRNGKey(8), (steps, batch, dim))
    h = jnp.asarray(0.1)
    tm = jnp.asarray([0.5, -0.2])
    t2 = jnp.asarray([1.0, 0.7])

    loss_fn = lambda p: m.moment_loss(p, y0, dws, h, tm, t2, use_pallas=False)
    g = jax.grad(loss_fn)(params)
    # FD spot-check on one weight entry.
    eps = 1e-6
    w = params["drift"][0][0]
    delta = jnp.zeros_like(w).at[0, 0].set(eps)
    pp = jax.tree_util.tree_map(lambda x: x, params)
    pp["drift"][0] = (w + delta, params["drift"][0][1])
    pm = jax.tree_util.tree_map(lambda x: x, params)
    pm["drift"][0] = (w - delta, params["drift"][0][1])
    fd = (loss_fn(pp) - loss_fn(pm)) / (2 * eps)
    np.testing.assert_allclose(g["drift"][0][0][0, 0], fd, rtol=1e-4, atol=1e-8)


def test_loss_and_grad_artifact_signature():
    params = make_params(dim=2, width=4, depth=1)
    steps, batch, dim = 4, 3, 2
    out = m.loss_and_grad(
        params,
        jnp.zeros((batch, dim)),
        0.1 * jax.random.normal(jax.random.PRNGKey(9), (steps, batch, dim)),
        jnp.asarray(0.1),
        jnp.zeros((dim,)),
        jnp.ones((dim,)),
        use_pallas=False,
    )
    # (loss, *flat grads): loss scalar + one array per (w, b) pair.
    n_arrays = sum(len(layer) for layer in params["drift"]) + sum(
        len(layer) for layer in params["diffusion"]
    )
    assert len(out) == 1 + n_arrays
    assert out[0].shape == ()
