//! Bench: Table 4 / Figure 6 / Table 14 — latent SDE on the sphere.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { ees::experiments::Scale::Full } else { ees::experiments::Scale::Smoke };
    println!("{}", ees::experiments::tab4::run(scale));
    let (n, steps): (usize, Vec<usize>) = if std::env::args().any(|a| a == "--full") {
        (16, vec![50, 200, 800, 2000, 5000])
    } else {
        (6, vec![50, 200, 800])
    };
    println!("{}", ees::experiments::tab4::run_memory(n, &steps));
}
