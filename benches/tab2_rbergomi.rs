//! Bench: Table 2 — rough Bergomi at fixed eval budget.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { ees::experiments::Scale::Full } else { ees::experiments::Scale::Smoke };
    use ees::models::stochvol::VolModel;
    println!("{}", ees::experiments::tab2::run(scale, &[VolModel::RoughBergomi]));
}
