//! Perf bench: the L3 hot paths — batched EES(2,5) stepping, the
//! reversible-adjoint forward+backward sweep, and the parallel batch engine
//! against its sequential path — timed with the in-crate harness. This is
//! the target of the EXPERIMENTS.md §Perf iteration log.

use ees::adjoint::AdjointMethod;
use ees::bench::{bench, speedup};
use ees::coordinator::{
    batch_grad_euclidean, batch_grad_euclidean_par, batch_integrate_par, sample_paths_par,
};
use ees::lie::TTorus;
use ees::losses::MomentMatch;
use ees::nn::neural_sde::{NeuralSde, TorusNeuralSde};
use ees::rng::{BrownianPath, Pcg64};
use ees::solvers::{CfEes, LowStorageStepper, ManifoldStepper, Stepper};
use ees::vf::DiffVectorField;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let iters = if full { 30 } else { 10 };

    // --- hot path 1: batched Euclidean EES(2,5) forward stepping ---------
    {
        let mut rng = Pcg64::new(1);
        let dim = 32;
        let model = NeuralSde::lsde(dim, 64, 2, false, &mut rng);
        let st = LowStorageStepper::ees25();
        let steps = 100;
        let h = 0.01;
        let path = BrownianPath::sample(&mut rng, dim, steps, h);
        let mut state = vec![0.1; dim];
        let s = bench("euclidean_ees25_forward_100steps_d32", 2, iters, || {
            let mut y = state.clone();
            for n in 0..steps {
                st.step(&model, n as f64 * h, h, path.increment(n), &mut y);
            }
            state[0] = state[0].max(-1e308); // keep side effect
            std::hint::black_box(&y);
        });
        println!("{}", s.report());
        let evals = steps * 3;
        println!(
            "  -> {:.2} us/vf-eval (dim {dim}, width 64)",
            s.mean_secs * 1e6 / evals as f64
        );
    }

    // --- hot path 2: reversible adjoint fwd+bwd (training inner loop) ----
    {
        let mut rng = Pcg64::new(2);
        let dim = 8;
        let model = NeuralSde::lsde(dim, 32, 2, false, &mut rng);
        let st = LowStorageStepper::ees25();
        let steps = 50;
        let h = 0.02;
        let batch = 16;
        let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.1; dim]).collect();
        let paths: Vec<BrownianPath> = (0..batch)
            .map(|_| BrownianPath::sample(&mut rng, dim, steps, h))
            .collect();
        let obs = vec![steps];
        let loss = MomentMatch {
            target_mean: vec![0.0; dim],
            target_m2: vec![1.0; dim],
        };
        let s = bench("reversible_adjoint_fwd_bwd_b16_s50_d8", 1, iters, || {
            let out = batch_grad_euclidean(
                &st,
                AdjointMethod::Reversible,
                &model,
                &y0s,
                &paths,
                &obs,
                &loss,
            );
            std::hint::black_box(&out);
        });
        println!("{}", s.report());
        println!(
            "  -> {:.2} us/step incl. backprop ({} params)",
            s.mean_secs * 1e6 / (batch * steps) as f64,
            model.num_params()
        );
    }

    // --- hot path 3: CF-EES stepping on T T^N (geometric hot loop) -------
    {
        let n_osc = if full { 1000 } else { 100 };
        let mut rng = Pcg64::new(3);
        let model = TorusNeuralSde::new(n_osc, 128, &mut rng);
        let sp = TTorus::new(n_osc);
        let st = CfEes::ees25();
        let steps = 20;
        let h = 0.01;
        let path = BrownianPath::sample(&mut rng, n_osc, steps, h);
        let y0 = vec![0.1; 2 * n_osc];
        let s = bench(
            &format!("cfees25_forward_20steps_TT{n_osc}_w128"),
            1,
            iters.min(10),
            || {
                let mut y = y0.clone();
                for n in 0..steps {
                    st.step(&sp, &model, n as f64 * h, h, path.increment(n), &mut y);
                }
                std::hint::black_box(&y);
            },
        );
        println!("{}", s.report());
        println!(
            "  -> {:.1} us/step ({} oscillators, 3 evals + 3 exps per step)",
            s.mean_secs * 1e6 / steps as f64,
            n_osc
        );
    }

    // --- hot path 4: parallel batch engine vs the sequential path --------
    // Batch simulation + reversible fwd+bwd at parallelism 1 vs 4. The
    // engine's contract is bitwise-identical outputs at any worker count;
    // the acceptance bar is >= 2x wall-clock at parallelism 4.
    {
        let mut rng = Pcg64::new(4);
        let dim = 16;
        let model = NeuralSde::lsde(dim, 64, 2, false, &mut rng);
        let st = LowStorageStepper::ees25();
        let steps = 100;
        let h = 0.01;
        let batch = 32;
        let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.1; dim]).collect();
        // Per-sample Pcg64 split streams: the batch is a pure function of
        // the parent seed, independent of worker count and schedule.
        let paths = sample_paths_par(&mut rng, batch, dim, steps, h, 4);
        let obs = vec![steps];
        let loss = MomentMatch {
            target_mean: vec![0.0; dim],
            target_m2: vec![1.0; dim],
        };

        // Batch trajectory generation.
        let sim_seq = bench("batch_integrate_b32_s100_d16 (P=1)", 1, iters, || {
            let t = batch_integrate_par(&st, &model, 0.0, &y0s, &paths, 1);
            std::hint::black_box(&t);
        });
        let sim_par = bench("batch_integrate_b32_s100_d16 (P=4)", 1, iters, || {
            let t = batch_integrate_par(&st, &model, 0.0, &y0s, &paths, 4);
            std::hint::black_box(&t);
        });
        let sim_same = batch_integrate_par(&st, &model, 0.0, &y0s, &paths, 1)
            .iter()
            .zip(batch_integrate_par(&st, &model, 0.0, &y0s, &paths, 4).iter())
            .all(|(a, b)| a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits()));
        println!("{}", sim_seq.report());
        println!("{}", sim_par.report());
        println!(
            "  -> batch simulation speedup at P=4: {:.2}x (outputs bitwise-identical: {})",
            speedup(&sim_seq, &sim_par),
            sim_same
        );

        // Reversible-adjoint forward+backward.
        let grad_seq = bench("batch_grad_reversible_b32_s100_d16 (P=1)", 1, iters, || {
            let out = batch_grad_euclidean_par(
                &st,
                AdjointMethod::Reversible,
                &model,
                &y0s,
                &paths,
                &obs,
                &loss,
                1,
            );
            std::hint::black_box(&out);
        });
        let grad_par = bench("batch_grad_reversible_b32_s100_d16 (P=4)", 1, iters, || {
            let out = batch_grad_euclidean_par(
                &st,
                AdjointMethod::Reversible,
                &model,
                &y0s,
                &paths,
                &obs,
                &loss,
                4,
            );
            std::hint::black_box(&out);
        });
        let (l1, g1, m1) = batch_grad_euclidean_par(
            &st,
            AdjointMethod::Reversible,
            &model,
            &y0s,
            &paths,
            &obs,
            &loss,
            1,
        );
        let (l4, g4, m4) = batch_grad_euclidean_par(
            &st,
            AdjointMethod::Reversible,
            &model,
            &y0s,
            &paths,
            &obs,
            &loss,
            4,
        );
        let grad_same = l1.to_bits() == l4.to_bits()
            && m1 == m4
            && g1
                .iter()
                .zip(g4.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits());
        println!("{}", grad_seq.report());
        println!("{}", grad_par.report());
        println!(
            "  -> fwd+bwd speedup at P=4: {:.2}x (outputs bitwise-identical: {})",
            speedup(&grad_seq, &grad_par),
            grad_same
        );
        assert!(grad_same && sim_same, "parallel engine must be bitwise-deterministic");
    }
}
