//! Bench: Figure 9 — EES(2,7) vs EES(2,5) under non-smooth fields.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { ees::experiments::Scale::Full } else { ees::experiments::Scale::Smoke };
    println!("{}", ees::experiments::fig9::run(scale));
}
