//! Bench: Table 1 / Figure 4 — OU dynamics at fixed eval budget.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { ees::experiments::Scale::Full } else { ees::experiments::Scale::Smoke };
    println!("{}", ees::experiments::tab1::run(scale));
}
