//! Bench: Figure 2 — absolute stability domains (with ASCII rendering).
fn main() {
    println!("{}", ees::experiments::fig2::run(true));
}
