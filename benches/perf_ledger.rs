//! The benchmark ledger runner: times the solver-step, expm, batch-grad and
//! forward/reverse-sweep hot paths on (a) the zero-allocation workspace path
//! and (b) a live allocate-per-step baseline (`PerStepAlloc` adapters that
//! reproduce the seed's per-step heap traffic), counts allocations per
//! operation through the registered counting allocator, and emits
//! `BENCH_hotpath.json`.
//!
//! Usage:
//!   cargo bench --bench perf_ledger                   # quick mode, print only
//!   cargo bench --bench perf_ledger -- --full         # more iterations
//!   cargo bench --bench perf_ledger -- --update       # rewrite BENCH_hotpath.json
//!   cargo bench --bench perf_ledger -- --check        # perf-regression gate:
//!       compare each arm's within-run speedup against the committed
//!       BENCH_hotpath.json (read before any --update rewrite) and exit
//!       non-zero on a >25% speedup drop or a lane-acceptance
//!       (batch_grad_lanes >= 1.5x) failure; speedups, not absolute ns/op,
//!       so the gate is portable across CI runner hardware
//!
//! Built with `--features simd`, the ledger grows `simd_dot/*`,
//! `simd_matmul_lanes/*` and `batch_grad_lanes_simd/*` arms whose baseline
//! column is the same kernel with the SIMD knob off, so `speedup` reads
//! directly as the SIMD win over the scalar reference kernels; in `--full
//! --check` runs those arms gate at >= 1.3x (quick mode is too noisy to
//! gate on). `regressions_vs` skips arms absent on either side, so a
//! default-build `--check` against a simd-build ledger still works.
//!
//! The `serve/simulate_coalesce/*` arms drive an in-process `ees serve`
//! engine with closed-loop clients: the workspace column coalesces
//! concurrent requests into lane groups, the baseline column dispatches
//! each request solo, so `speedup` reads as the dynamic-batching win. In
//! `--full --check` runs the 8-client arm gates at >= 2.0x; the 1-client
//! arm stays informational (a lone client pays the batch window as a
//! latency tax, so its column reads below 1x by design).

use ees::adjoint::{grad_euclidean, AdjointMethod, MseToTargets};
use ees::bench::ledger::{
    allocs_per_op, median_ns, Ledger, LedgerEntry, PerStepAlloc, PerStepAllocManifold,
};
use ees::lie::{HomogeneousSpace, Sphere, TTorus};
use ees::linalg::{expm, expm_frechet, expm_frechet_into, expm_into};
use ees::memory::StepWorkspace;
use ees::rng::{BrownianPath, Pcg64};
use ees::solvers::{
    CfEes, CrouchGrossman, EmbeddedEes25, GeoEulerMaruyama, LowStorageStepper, ManifoldStepper,
    Mcf, ReversibleHeun, Rkmk, RkStepper, Stepper,
};
use ees::vf::{ClosureManifoldField, DiffVectorField, VectorField};

#[global_allocator]
static ALLOC: ees::bench::CountingAlloc = ees::bench::CountingAlloc;

/// Allocation-free analytic SDE field (dim 16): the solver machinery, not
/// the field, dominates — which is exactly what the ledger tracks.
struct Analytic16;

impl VectorField for Analytic16 {
    fn dim(&self) -> usize {
        16
    }
    fn noise_dim(&self) -> usize {
        16
    }
    fn combined(&self, _t: f64, y: &[f64], h: f64, dw: &[f64], out: &mut [f64]) {
        for i in 0..16 {
            let yn = y[(i + 1) % 16];
            out[i] = (-0.5 * y[i] + 0.25 * yn * yn.tanh()) * h + 0.2 * y[i] * dw[i];
        }
    }
}

impl DiffVectorField for Analytic16 {
    fn num_params(&self) -> usize {
        0
    }
    fn vjp(
        &self,
        _t: f64,
        y: &[f64],
        h: f64,
        dw: &[f64],
        cot: &[f64],
        d_y: &mut [f64],
        _d_theta: &mut [f64],
    ) {
        for i in 0..16 {
            d_y[i] += cot[i] * (-0.5 * h + 0.2 * dw[i]);
            let t = y[i].tanh();
            let prev = (i + 15) % 16;
            d_y[i] += cot[prev] * 0.25 * (t + y[i] * (1.0 - t * t)) * h;
        }
    }
}

fn sphere_field(n: usize) -> ClosureManifoldField<
    impl Fn(f64, &[f64], f64, &[f64], &mut [f64]) + Send + Sync,
> {
    let g = n * (n - 1) / 2;
    ClosureManifoldField {
        point_dim: n,
        algebra_dim: g,
        noise_dim: 2,
        gen: move |_t, y: &[f64], h: f64, dw: &[f64], out: &mut [f64]| {
            let mut k = 0;
            for i in 0..n {
                for j in i + 1..n {
                    out[k] = (0.05 * y[i] - 0.03 * y[j]) * h + 0.02 * y[j] * dw[0]
                        - 0.01 * y[i] * dw[1];
                    k += 1;
                }
            }
        },
    }
}

fn torus_field(n: usize) -> ClosureManifoldField<
    impl Fn(f64, &[f64], f64, &[f64], &mut [f64]) + Send + Sync,
> {
    ClosureManifoldField {
        point_dim: 2 * n,
        algebra_dim: 2 * n,
        noise_dim: n,
        gen: move |_t, y: &[f64], h: f64, dw: &[f64], out: &mut [f64]| {
            for i in 0..n {
                out[i] = y[n + i] * h;
                out[n + i] = (y[i].sin() - 0.1 * y[n + i]) * h + 0.3 * dw[i];
            }
        },
    }
}

/// Time `steps` Euclidean steps per op on both arms.
fn euclidean_step_entry(
    name: &str,
    ws_st: &dyn Stepper,
    base_st: &dyn Stepper,
    vf: &dyn VectorField,
    path: &BrownianPath,
    steps: usize,
    warmup: usize,
    iters: usize,
) -> LedgerEntry {
    let y0 = vec![0.1; vf.dim()];
    let run = |st: &dyn Stepper, ws: &mut StepWorkspace| {
        let mut state = st.init_state(vf, 0.0, &y0);
        for n in 0..steps {
            st.step_ws(vf, n as f64 * path.h, path.h, path.increment(n), &mut state, ws);
        }
        std::hint::black_box(&state);
    };
    let mut ws = StepWorkspace::new();
    let median = median_ns(warmup, iters, || run(ws_st, &mut ws)) / steps as f64;
    let allocs = {
        // One trajectory's worth of steps, after warm-up; init_state's own
        // allocation is excluded by measuring pure stepping.
        let mut state = ws_st.init_state(vf, 0.0, &y0);
        ws_st.step_ws(vf, 0.0, path.h, path.increment(0), &mut state, &mut ws);
        allocs_per_op(steps, || {
            for n in 0..steps {
                ws_st.step_ws(vf, n as f64 * path.h, path.h, path.increment(n), &mut state, &mut ws);
            }
        })
    };
    let mut ws_b = StepWorkspace::new();
    let base_median = median_ns(warmup, iters, || run(base_st, &mut ws_b)) / steps as f64;
    let base_allocs = {
        let mut state = base_st.init_state(vf, 0.0, &y0);
        allocs_per_op(steps, || {
            for n in 0..steps {
                base_st.step_ws(
                    vf,
                    n as f64 * path.h,
                    path.h,
                    path.increment(n),
                    &mut state,
                    &mut ws_b,
                );
            }
        })
    };
    LedgerEntry {
        name: name.into(),
        median_ns: median,
        allocs_per_op: allocs,
        baseline_median_ns: base_median,
        baseline_allocs_per_op: base_allocs,
    }
}

/// Time `steps` manifold steps per op on both arms.
fn manifold_step_entry(
    name: &str,
    ws_st: &dyn ManifoldStepper,
    base_st: &dyn ManifoldStepper,
    sp: &dyn HomogeneousSpace,
    vf: &dyn ees::vf::ManifoldVectorField,
    y0: &[f64],
    path: &BrownianPath,
    steps: usize,
    warmup: usize,
    iters: usize,
) -> LedgerEntry {
    let run = |st: &dyn ManifoldStepper, ws: &mut StepWorkspace| {
        let mut y = ws.take_copy(y0);
        for n in 0..steps {
            st.step_ws(sp, vf, n as f64 * path.h, path.h, path.increment(n), &mut y, ws);
        }
        std::hint::black_box(&y);
        ws.put(y);
    };
    let mut ws = StepWorkspace::new();
    let median = median_ns(warmup, iters, || run(ws_st, &mut ws)) / steps as f64;
    let allocs = {
        let mut y = ws.take_copy(y0);
        ws_st.step_ws(sp, vf, 0.0, path.h, path.increment(0), &mut y, &mut ws);
        let a = allocs_per_op(steps, || {
            for n in 0..steps {
                ws_st.step_ws(sp, vf, n as f64 * path.h, path.h, path.increment(n), &mut y, &mut ws);
            }
        });
        ws.put(y);
        a
    };
    let mut ws_b = StepWorkspace::new();
    let base_median = median_ns(warmup, iters, || run(base_st, &mut ws_b)) / steps as f64;
    let base_allocs = {
        let mut y = ws_b.take_copy(y0);
        let a = allocs_per_op(steps, || {
            for n in 0..steps {
                base_st.step_ws(
                    sp,
                    vf,
                    n as f64 * path.h,
                    path.h,
                    path.increment(n),
                    &mut y,
                    &mut ws_b,
                );
            }
        });
        ws_b.put(y);
        a
    };
    LedgerEntry {
        name: name.into(),
        median_ns: median,
        allocs_per_op: allocs,
        baseline_median_ns: base_median,
        baseline_allocs_per_op: base_allocs,
    }
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let update = std::env::args().any(|a| a == "--update");
    let check = std::env::args().any(|a| a == "--check");
    let iters = if full { 60 } else { 15 };
    let warmup = if full { 10 } else { 3 };
    let mut ledger = Ledger::new(if full { "full" } else { "quick" });

    // Pin the SIMD knob off for every scalar arm regardless of `EES_SIMD`
    // in the environment; the simd_* arms toggle it explicitly around each
    // measurement. (No-op in a default build.)
    ees::linalg::set_simd(false);

    let mut rng = Pcg64::new(7);
    let steps = 64;
    let h = 0.01;
    let path16 = BrownianPath::sample(&mut rng, 16, steps, h);

    // --- solver-step microbenches: all nine solver families --------------
    let vf = Analytic16;
    ledger.push(euclidean_step_entry(
        "step/rk_ees25/d16",
        &RkStepper::ees25(),
        &PerStepAlloc(RkStepper::ees25()),
        &vf,
        &path16,
        steps,
        warmup,
        iters,
    ));
    ledger.push(euclidean_step_entry(
        "step/lowstorage_ees25/d16",
        &LowStorageStepper::ees25(),
        &PerStepAlloc(LowStorageStepper::ees25()),
        &vf,
        &path16,
        steps,
        warmup,
        iters,
    ));
    ledger.push(euclidean_step_entry(
        "step/reversible_heun/d16",
        &ReversibleHeun::new(),
        &PerStepAlloc(ReversibleHeun::new()),
        &vf,
        &path16,
        steps,
        warmup,
        iters,
    ));
    ledger.push(euclidean_step_entry(
        "step/mcf_midpoint/d16",
        &Mcf::midpoint(),
        &PerStepAlloc(Mcf::midpoint()),
        &vf,
        &path16,
        steps,
        warmup,
        iters,
    ));
    // Embedded (adaptive) scheme: time step_embedded on both arms.
    {
        let sch = EmbeddedEes25::new();
        let dw = vec![0.0; 16];
        let mut ws = StepWorkspace::new();
        let median = median_ns(warmup, iters, || {
            let mut y = vec![0.1; 16];
            for n in 0..steps {
                sch.step_embedded_ws(&vf, n as f64 * h, h, &dw, &mut y, &mut ws);
            }
            std::hint::black_box(&y);
        }) / steps as f64;
        let allocs = {
            let mut y = vec![0.1; 16];
            sch.step_embedded_ws(&vf, 0.0, h, &dw, &mut y, &mut ws);
            allocs_per_op(steps, || {
                for n in 0..steps {
                    sch.step_embedded_ws(&vf, n as f64 * h, h, &dw, &mut y, &mut ws);
                }
            })
        };
        let base_median = median_ns(warmup, iters, || {
            let mut y = vec![0.1; 16];
            for n in 0..steps {
                sch.step_embedded(&vf, n as f64 * h, h, &dw, &mut y);
            }
            std::hint::black_box(&y);
        }) / steps as f64;
        let base_allocs = {
            let mut y = vec![0.1; 16];
            allocs_per_op(steps, || {
                for n in 0..steps {
                    sch.step_embedded(&vf, n as f64 * h, h, &dw, &mut y);
                }
            })
        };
        ledger.push(LedgerEntry {
            name: "step/embedded_ees25/d16".into(),
            median_ns: median,
            allocs_per_op: allocs,
            baseline_median_ns: base_median,
            baseline_allocs_per_op: base_allocs,
        });
    }

    // Manifold families. CF-EES on S^15 is the acceptance microbench: the
    // step cost is dominated by expm/Fréchet panels, where the blocked
    // kernels and workspace reuse land.
    {
        let n = 16;
        let sp = Sphere::new(n);
        let svf = sphere_field(n);
        let mut y0 = vec![0.0; n];
        y0[0] = 1.0;
        let mpath = BrownianPath::sample(&mut rng, 2, steps, h);
        ledger.push(manifold_step_entry(
            "step/cfees25/sphere16",
            &CfEes::ees25(),
            &PerStepAllocManifold(CfEes::ees25()),
            &sp,
            &svf,
            &y0,
            &mpath,
            steps,
            warmup.min(3),
            iters.min(20),
        ));
        ledger.push(manifold_step_entry(
            "step/rkmk_srkmk3/sphere16",
            &Rkmk::srkmk3(),
            &PerStepAllocManifold(Rkmk::srkmk3()),
            &sp,
            &svf,
            &y0,
            &mpath,
            steps,
            warmup.min(3),
            iters.min(20),
        ));
        ledger.push(manifold_step_entry(
            "step/cg3/sphere16",
            &CrouchGrossman::cg3(),
            &PerStepAllocManifold(CrouchGrossman::cg3()),
            &sp,
            &svf,
            &y0,
            &mpath,
            steps,
            warmup.min(3),
            iters.min(20),
        ));
        ledger.push(manifold_step_entry(
            "step/geo_em/sphere16",
            &GeoEulerMaruyama::new(),
            &PerStepAllocManifold(GeoEulerMaruyama::new()),
            &sp,
            &svf,
            &y0,
            &mpath,
            steps,
            warmup.min(3),
            iters.min(20),
        ));
    }
    {
        let n_osc = 64;
        let sp = TTorus::new(n_osc);
        let tvf = torus_field(n_osc);
        let y0 = vec![0.1; 2 * n_osc];
        let tpath = BrownianPath::sample(&mut rng, n_osc, steps, h);
        ledger.push(manifold_step_entry(
            "step/cfees25/ttorus64",
            &CfEes::ees25(),
            &PerStepAllocManifold(CfEes::ees25()),
            &sp,
            &tvf,
            &y0,
            &tpath,
            steps,
            warmup,
            iters,
        ));
    }

    // --- expm kernel benches ---------------------------------------------
    for n in [4usize, 8, 16] {
        let mut a = vec![0.0; n * n];
        let mut r = Pcg64::new(100 + n as u64);
        r.fill_normal(&mut a);
        for x in a.iter_mut() {
            *x *= 0.3;
        }
        let mut ws = StepWorkspace::new();
        let mut out = vec![0.0; n * n];
        let reps = 32;
        let median = median_ns(warmup, iters, || {
            for _ in 0..reps {
                expm_into(&a, &mut out, n, &mut ws);
                std::hint::black_box(&out);
            }
        }) / reps as f64;
        let allocs = allocs_per_op(reps, || {
            for _ in 0..reps {
                expm_into(&a, &mut out, n, &mut ws);
            }
        });
        let base_median = median_ns(warmup, iters, || {
            for _ in 0..reps {
                std::hint::black_box(expm(&a, n));
            }
        }) / reps as f64;
        let base_allocs = allocs_per_op(reps, || {
            for _ in 0..reps {
                std::hint::black_box(expm(&a, n));
            }
        });
        ledger.push(LedgerEntry {
            name: format!("expm/{n}"),
            median_ns: median,
            allocs_per_op: allocs,
            baseline_median_ns: base_median,
            baseline_allocs_per_op: base_allocs,
        });
    }
    {
        let n = 8;
        let mut r = Pcg64::new(42);
        let mut a = vec![0.0; n * n];
        let mut e = vec![0.0; n * n];
        r.fill_normal(&mut a);
        r.fill_normal(&mut e);
        for x in a.iter_mut() {
            *x *= 0.2;
        }
        let mut ws = StepWorkspace::new();
        let (mut ea, mut l) = (vec![0.0; n * n], vec![0.0; n * n]);
        let reps = 16;
        let median = median_ns(warmup, iters, || {
            for _ in 0..reps {
                expm_frechet_into(&a, &e, &mut ea, &mut l, n, &mut ws);
                std::hint::black_box(&l);
            }
        }) / reps as f64;
        let allocs = allocs_per_op(reps, || {
            for _ in 0..reps {
                expm_frechet_into(&a, &e, &mut ea, &mut l, n, &mut ws);
            }
        });
        let base_median = median_ns(warmup, iters, || {
            for _ in 0..reps {
                std::hint::black_box(expm_frechet(&a, &e, n));
            }
        }) / reps as f64;
        let base_allocs = allocs_per_op(reps, || {
            for _ in 0..reps {
                std::hint::black_box(expm_frechet(&a, &e, n));
            }
        });
        ledger.push(LedgerEntry {
            name: format!("expm_frechet/{n}"),
            median_ns: median,
            allocs_per_op: allocs,
            baseline_median_ns: base_median,
            baseline_allocs_per_op: base_allocs,
        });
    }

    // --- forward+reverse sweep and batch-grad ----------------------------
    {
        let dim = 16;
        let sweep_steps = 50;
        let path = BrownianPath::sample(&mut rng, dim, sweep_steps, 0.02);
        let obs = vec![sweep_steps];
        let loss = MseToTargets {
            targets: vec![0.0; dim],
        };
        let st = LowStorageStepper::ees25();
        let base = PerStepAlloc(LowStorageStepper::ees25());
        let y0 = vec![0.1; dim];
        let run = |stepper: &dyn Stepper| {
            let g = grad_euclidean(
                stepper,
                AdjointMethod::Reversible,
                &vf,
                0.0,
                &y0,
                &path,
                &obs,
                &loss,
            );
            std::hint::black_box(&g);
        };
        let median = median_ns(warmup, iters, || run(&st)) / sweep_steps as f64;
        let allocs = allocs_per_op(sweep_steps, || run(&st));
        let base_median = median_ns(warmup, iters, || run(&base)) / sweep_steps as f64;
        let base_allocs = allocs_per_op(sweep_steps, || run(&base));
        ledger.push(LedgerEntry {
            name: "sweep/reversible_fwd_bwd/d16_s50".into(),
            median_ns: median,
            allocs_per_op: allocs,
            baseline_median_ns: base_median,
            baseline_allocs_per_op: base_allocs,
        });
    }
    {
        use ees::coordinator::{batch_grad_euclidean_par, sample_paths_par};
        use ees::losses::MomentMatch;
        let dim = 16;
        let (batch, bsteps) = (16, 50);
        let mut brng = Pcg64::new(11);
        let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.1; dim]).collect();
        let paths = sample_paths_par(&mut brng, batch, dim, bsteps, 0.02, 1);
        let obs = vec![bsteps];
        let loss = MomentMatch {
            target_mean: vec![0.0; dim],
            target_m2: vec![1.0; dim],
        };
        let st = LowStorageStepper::ees25();
        let base = PerStepAlloc(LowStorageStepper::ees25());
        let ops = batch * bsteps;
        let run = |stepper: &dyn Stepper| {
            let out = batch_grad_euclidean_par(
                stepper,
                AdjointMethod::Reversible,
                &vf,
                &y0s,
                &paths,
                &obs,
                &loss,
                1,
            );
            std::hint::black_box(&out);
        };
        let median = median_ns(warmup, iters, || run(&st)) / ops as f64;
        let allocs = allocs_per_op(ops, || run(&st));
        let base_median = median_ns(warmup, iters, || run(&base)) / ops as f64;
        let base_allocs = allocs_per_op(ops, || run(&base));
        ledger.push(LedgerEntry {
            name: "batch_grad/reversible_ees25/b16_s50_d16".into(),
            median_ns: median,
            allocs_per_op: allocs,
            baseline_median_ns: base_median,
            baseline_allocs_per_op: base_allocs,
        });
    }

    // --- lane-blocked stepping: lane group vs per-sample loop ------------
    // The lane arms use an MLP field (where per-sample evaluation is
    // matvec-shaped): the "workspace" column is the lane-blocked group
    // step, the "baseline" column steps the same samples one at a time, so
    // `speedup` reads directly as the lane-blocking win.
    {
        use ees::linalg::lane_scatter;
        use ees::nn::neural_sde::NeuralSde;
        let lanes = 8usize;
        let dim = 16usize;
        let model = NeuralSde::lsde(dim, 32, 2, false, &mut Pcg64::new(3));
        let lsteps = 64usize;
        let lpath = BrownianPath::sample(&mut rng, dim, lsteps, h);
        // Lane-major noise blocks, prepacked outside the timed region.
        let dw_blocks: Vec<Vec<f64>> = (0..lsteps)
            .map(|n| {
                let mut blk = vec![0.0; dim * lanes];
                for l in 0..lanes {
                    lane_scatter(lpath.increment(n), l, lanes, &mut blk);
                }
                blk
            })
            .collect();
        let ls = LowStorageStepper::ees25();
        let rh = ReversibleHeun::new();
        let lane_steppers: [(&str, &dyn Stepper); 2] = [
            ("lane_step/lowstorage_ees25/d16_l8", &ls),
            ("lane_step/reversible_heun/d16_l8", &rh),
        ];
        let y0 = vec![0.1; dim];
        for (name, st) in lane_steppers {
            let ss = st.state_size(dim);
            let mut ws = StepWorkspace::new();
            let run_lanes = |ws: &mut StepWorkspace| {
                let mut state = ws.take(ss * lanes);
                let init = st.init_state(&model, 0.0, &y0);
                for l in 0..lanes {
                    lane_scatter(&init, l, lanes, &mut state);
                }
                for (n, dw) in dw_blocks.iter().enumerate() {
                    st.step_lanes_ws(&model, n as f64 * h, h, dw, &mut state, lanes, ws);
                }
                std::hint::black_box(&state);
                ws.put(state);
            };
            let ops = lsteps * lanes;
            let median = median_ns(warmup, iters, || run_lanes(&mut ws)) / ops as f64;
            let allocs = {
                run_lanes(&mut ws);
                allocs_per_op(ops, || run_lanes(&mut ws))
            };
            let mut ws_b = StepWorkspace::new();
            let run_scalar = |ws: &mut StepWorkspace| {
                for _l in 0..lanes {
                    let mut state = st.init_state(&model, 0.0, &y0);
                    for n in 0..lsteps {
                        st.step_ws(&model, n as f64 * h, h, lpath.increment(n), &mut state, ws);
                    }
                    std::hint::black_box(&state);
                }
            };
            let base_median = median_ns(warmup, iters, || run_scalar(&mut ws_b)) / ops as f64;
            let base_allocs = allocs_per_op(ops, || run_scalar(&mut ws_b));
            ledger.push(LedgerEntry {
                name: name.into(),
                median_ns: median,
                allocs_per_op: allocs,
                baseline_median_ns: base_median,
                baseline_allocs_per_op: base_allocs,
            });
        }

        // Embedded scheme's fixed-grid lane arm vs per-sample embedded
        // stepping — the lane-blocked error-estimating step the adaptive
        // family's batch fixed-grid workloads use.
        {
            let sch = EmbeddedEes25::new();
            let mut ws = StepWorkspace::new();
            let mut err = vec![0.0; lanes];
            let run_lanes = |ws: &mut StepWorkspace, err: &mut [f64]| {
                let mut y = ws.take(dim * lanes);
                for l in 0..lanes {
                    lane_scatter(&y0, l, lanes, &mut y);
                }
                for (n, dwb) in dw_blocks.iter().enumerate() {
                    sch.step_embedded_lanes_ws(&model, n as f64 * h, h, dwb, &mut y, err, lanes, ws);
                }
                std::hint::black_box(&y);
                ws.put(y);
            };
            let ops = lsteps * lanes;
            let median = median_ns(warmup, iters, || run_lanes(&mut ws, &mut err)) / ops as f64;
            let allocs = {
                run_lanes(&mut ws, &mut err);
                allocs_per_op(ops, || run_lanes(&mut ws, &mut err))
            };
            let mut ws_b = StepWorkspace::new();
            let run_scalar = |ws: &mut StepWorkspace| {
                for _l in 0..lanes {
                    let mut y = y0.clone();
                    for n in 0..lsteps {
                        std::hint::black_box(sch.step_embedded_ws(
                            &model,
                            n as f64 * h,
                            h,
                            lpath.increment(n),
                            &mut y,
                            ws,
                        ));
                    }
                    std::hint::black_box(&y);
                }
            };
            let base_median = median_ns(warmup, iters, || run_scalar(&mut ws_b)) / ops as f64;
            let base_allocs = allocs_per_op(ops, || run_scalar(&mut ws_b));
            ledger.push(LedgerEntry {
                name: "lane_step/embedded_ees25/d16_l8".into(),
                median_ns: median,
                allocs_per_op: allocs,
                baseline_median_ns: base_median,
                baseline_allocs_per_op: base_allocs,
            });
        }

        // Full batch gradient through the lane engine vs the per-sample
        // engine: the acceptance arm of the lane-blocked hot path (the CI
        // bench-smoke run gates on speedup >= 1.5 here).
        {
            use ees::coordinator::{batch_grad_euclidean_pool_lanes, sample_paths_par};
            use ees::losses::MomentMatch;
            use ees::memory::WorkspacePool;
            let (batch, bsteps) = (16usize, 50usize);
            let mut brng = Pcg64::new(13);
            let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.1; dim]).collect();
            let paths = sample_paths_par(&mut brng, batch, dim, bsteps, 0.02, 1);
            let obs = vec![bsteps];
            let loss = MomentMatch {
                target_mean: vec![0.0; dim],
                target_m2: vec![1.0; dim],
            };
            let st = LowStorageStepper::ees25();
            let pool = WorkspacePool::new();
            let ops = batch * bsteps;
            let run = |l: usize| {
                let out = batch_grad_euclidean_pool_lanes(
                    &st,
                    AdjointMethod::Reversible,
                    &model,
                    &y0s,
                    &paths,
                    &obs,
                    &loss,
                    1,
                    &pool,
                    l,
                );
                std::hint::black_box(&out);
            };
            let median = median_ns(warmup, iters, || run(lanes)) / ops as f64;
            let allocs = allocs_per_op(ops, || run(lanes));
            let base_median = median_ns(warmup, iters, || run(1)) / ops as f64;
            let base_allocs = allocs_per_op(ops, || run(1));
            ledger.push(LedgerEntry {
                name: "batch_grad_lanes/b16_s50_d16".into(),
                median_ns: median,
                allocs_per_op: allocs,
                baseline_median_ns: base_median,
                baseline_allocs_per_op: base_allocs,
            });
        }

        // Manifold lane stepping: CF-EES on SO(3), lane-major 9×L state
        // blocks through the per-lane Rodrigues exp against the same
        // samples stepped one at a time.
        {
            use ees::lie::So3;
            let sp = So3::new();
            let so3f = ClosureManifoldField {
                point_dim: 9,
                algebra_dim: 3,
                noise_dim: 2,
                gen: |_t, y: &[f64], hh: f64, dw: &[f64], out: &mut [f64]| {
                    out[0] = (0.2 * y[0] - 0.1 * y[4]) * hh + 0.3 * dw[0];
                    out[1] = 0.1 * y[8] * hh - 0.2 * dw[1];
                    out[2] = (0.05 * y[1] + 0.1 * y[3]) * hh + 0.1 * dw[0] - 0.05 * dw[1];
                },
            };
            let cf = CfEes::ees25();
            let y0 = ees::linalg::eye(3);
            let lsteps = 64usize;
            let mpath = BrownianPath::sample(&mut rng, 2, lsteps, h);
            let dw_blocks: Vec<Vec<f64>> = (0..lsteps)
                .map(|n| {
                    let mut blk = vec![0.0; 2 * lanes];
                    for l in 0..lanes {
                        lane_scatter(mpath.increment(n), l, lanes, &mut blk);
                    }
                    blk
                })
                .collect();
            let mut ws = StepWorkspace::new();
            let run_lanes = |ws: &mut StepWorkspace| {
                let mut y = ws.take(9 * lanes);
                for l in 0..lanes {
                    lane_scatter(&y0, l, lanes, &mut y);
                }
                for (n, dwb) in dw_blocks.iter().enumerate() {
                    cf.step_lanes_ws(&sp, &so3f, n as f64 * h, h, dwb, &mut y, lanes, ws);
                }
                std::hint::black_box(&y);
                ws.put(y);
            };
            let ops = lsteps * lanes;
            let median = median_ns(warmup, iters, || run_lanes(&mut ws)) / ops as f64;
            let allocs = {
                run_lanes(&mut ws);
                allocs_per_op(ops, || run_lanes(&mut ws))
            };
            let mut ws_b = StepWorkspace::new();
            let run_scalar = |ws: &mut StepWorkspace| {
                for _l in 0..lanes {
                    let mut y = ws.take_copy(&y0);
                    for n in 0..lsteps {
                        cf.step_ws(&sp, &so3f, n as f64 * h, h, mpath.increment(n), &mut y, ws);
                    }
                    std::hint::black_box(&y);
                    ws.put(y);
                }
            };
            let base_median = median_ns(warmup, iters, || run_scalar(&mut ws_b)) / ops as f64;
            let base_allocs = allocs_per_op(ops, || run_scalar(&mut ws_b));
            ledger.push(LedgerEntry {
                name: "lane_step/cfees_so3".into(),
                median_ns: median,
                allocs_per_op: allocs,
                baseline_median_ns: base_median,
                baseline_allocs_per_op: base_allocs,
            });
        }

        // Full manifold batch gradient through the lane engine vs the
        // per-sample engine — the manifold acceptance arm (CI gates on
        // speedup >= 1.5 here too).
        {
            use ees::coordinator::{batch_grad_manifold_pool_lanes, sample_paths_par};
            use ees::losses::MomentMatch;
            use ees::memory::WorkspacePool;
            use ees::nn::neural_sde::TorusNeuralSde;
            let n_osc = 8usize;
            let sp = TTorus::new(n_osc);
            let tmodel = TorusNeuralSde::new(n_osc, 32, &mut Pcg64::new(17));
            let (batch, bsteps) = (16usize, 50usize);
            let mut brng = Pcg64::new(19);
            let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.2; 2 * n_osc]).collect();
            let paths = sample_paths_par(&mut brng, batch, n_osc, bsteps, 0.02, 1);
            let obs = vec![bsteps];
            let loss = MomentMatch {
                target_mean: vec![0.0; 2 * n_osc],
                target_m2: vec![1.0; 2 * n_osc],
            };
            let cf = CfEes::ees25();
            let pool = WorkspacePool::new();
            let ops = batch * bsteps;
            let run = |l: usize| {
                let out = batch_grad_manifold_pool_lanes(
                    &cf,
                    AdjointMethod::Reversible,
                    &sp,
                    &tmodel,
                    &y0s,
                    &paths,
                    &obs,
                    &loss,
                    1,
                    &pool,
                    l,
                );
                std::hint::black_box(&out);
            };
            let median = median_ns(warmup, iters, || run(lanes)) / ops as f64;
            let allocs = allocs_per_op(ops, || run(lanes));
            let base_median = median_ns(warmup, iters, || run(1)) / ops as f64;
            let base_allocs = allocs_per_op(ops, || run(1));
            ledger.push(LedgerEntry {
                name: "batch_grad_lanes/manifold".into(),
                median_ns: median,
                allocs_per_op: allocs,
                baseline_median_ns: base_median,
                baseline_allocs_per_op: base_allocs,
            });
        }
    }

    // --- risk-engine arms -------------------------------------------------
    // The fractional-kernel convolution the rough-Bergomi sweep spends its
    // time in: FFT (workspace column) vs the pinned O(n^2) direct reference
    // (baseline column) at the million-path fine-grid length, so `speedup`
    // reads as the FFT win the risk engine banks per path.
    {
        use ees::rng::fbm::{riemann_liouville_direct, riemann_liouville_fft};
        let n = 512usize;
        let dt = 1.0 / n as f64;
        let mut dw = vec![0.0; n];
        let mut r = Pcg64::new(61);
        r.fill_normal_scaled(dt.sqrt(), &mut dw);
        let median = median_ns(warmup, iters, || {
            std::hint::black_box(riemann_liouville_fft(0.07, dt, std::hint::black_box(&dw)));
        });
        let allocs = allocs_per_op(1, || {
            std::hint::black_box(riemann_liouville_fft(0.07, dt, &dw));
        });
        let base_median = median_ns(warmup.min(3), iters.min(20), || {
            std::hint::black_box(riemann_liouville_direct(0.07, dt, std::hint::black_box(&dw)));
        });
        let base_allocs = allocs_per_op(1, || {
            std::hint::black_box(riemann_liouville_direct(0.07, dt, &dw));
        });
        ledger.push(LedgerEntry {
            name: "risk/rl_fft_n512".into(),
            median_ns: median,
            allocs_per_op: allocs,
            baseline_median_ns: base_median,
            baseline_allocs_per_op: base_allocs,
        });
    }

    // A GBM-portfolio risk chunk end to end: the lane-blocked EES arm
    // (workspace column) vs the scalar diagonal-noise Milstein baseline arm
    // (baseline column) over the same 64-path chunk — the cost ratio a
    // sweep pays for the higher-order scheme family. Informational, not
    // gated.
    {
        use ees::config::Config;
        use ees::risk::{RiskConfig, RiskSweep};
        let mk = |stepper: &str| {
            RiskConfig::from_config(
                &Config::parse(&format!(
                    "[risk]\nscenario = \"gbm_portfolio\"\nstepper = \"{stepper}\"\n\
                     dim = 8\npaths = 64\nsteps = 32\nchunk = 64\nseed = 23\n\
                     [exec]\nparallelism = 1\nlanes = 8\n"
                ))
                .unwrap(),
            )
            .unwrap()
        };
        let (ees_cfg, mil_cfg) = (mk("ees"), mk("milstein"));
        let ops = 64usize;
        let median = median_ns(warmup, iters, || {
            let mut s = RiskSweep::new(ees_cfg.clone());
            s.run();
            std::hint::black_box(s.done());
        }) / ops as f64;
        let allocs = allocs_per_op(ops, || {
            let mut s = RiskSweep::new(ees_cfg.clone());
            s.run();
        });
        let base_median = median_ns(warmup, iters, || {
            let mut s = RiskSweep::new(mil_cfg.clone());
            s.run();
            std::hint::black_box(s.done());
        }) / ops as f64;
        let base_allocs = allocs_per_op(ops, || {
            let mut s = RiskSweep::new(mil_cfg.clone());
            s.run();
        });
        ledger.push(LedgerEntry {
            name: "risk/gbm_chunk_ees_vs_milstein/b64_s32_d8".into(),
            median_ns: median,
            allocs_per_op: allocs,
            baseline_median_ns: base_median,
            baseline_allocs_per_op: base_allocs,
        });
    }

    // --- serving-layer coalescing arms -----------------------------------
    // The tentpole number: closed-loop clients against an in-process `ees
    // serve` engine, identical traffic on two servers sharing one registry
    // — coalescing ON (workspace column: concurrent 1-path requests packed
    // into 8-wide lane groups) vs coalescing OFF (baseline column: solo
    // per-request dispatch). At 8 clients `speedup` reads directly as the
    // dynamic-batching win; the 1-client arm is the honest flip side — a
    // lone client pays the batch-formation window as a latency tax, so its
    // speedup column reads below 1x by design.
    {
        use ees::config::Config;
        use ees::serve::{Registry, Request, ServeConfig, Server, Workload};
        use std::sync::Arc;

        // Wide-model GBM scenario: per-step matvecs big enough that lane
        // blocking (not queueing noise) dominates the per-request cost.
        let cfg = Config::parse(
            "[serve]\nseed = 31\n\
             [serve.ou]\nsteps = 16\ndata_samples = 64\n\
             [serve.gbm]\ndim = 16\nsteps = 64\nhidden = 32\ndata_samples = 16\ndata_fine = 64\n\
             [exec]\nlanes = 8\n",
        )
        .unwrap();
        let registry = Arc::new(Registry::from_config(&cfg).unwrap());
        let mk = |coalesce: bool| ServeConfig {
            workers: 2,
            dispatch_parallelism: 1,
            lanes: 8,
            queue_depth: 4096,
            window_us: 200,
            max_batch: 32,
            max_paths: 64,
            coalesce,
            read_timeout_ms: 0,
            max_line_bytes: 64 * 1024,
            fault: ees::fault::FaultPlan::inert(),
        };
        let on = Server::start_shared(Arc::clone(&registry), mk(true));
        let off = Server::start_shared(Arc::clone(&registry), mk(false));
        // One closed-loop burst: `clients` threads, `per` requests each,
        // one in flight per client.
        let drive = |server: &Server, clients: usize, per: usize| {
            std::thread::scope(|scope| {
                for c in 0..clients {
                    let server = &*server;
                    scope.spawn(move || {
                        for k in 0..per {
                            let id = (c * per + k) as u64;
                            let resp = server.call(Request {
                                id,
                                scenario: "gbm".to_string(),
                                workload: Workload::Simulate,
                                paths: 1,
                                seed: 1000 + id,
                            });
                            assert!(!resp.is_rejected());
                        }
                    });
                }
            });
        };
        let per = if full { 8usize } else { 4 };
        for (arm, clients) in [("c8_p1", 8usize), ("c1_p1", 1)] {
            let ops = clients * per;
            drive(&on, clients, per); // warm both servers' worker pools
            drive(&off, clients, per);
            let median =
                median_ns(warmup.min(3), iters.min(20), || drive(&on, clients, per)) / ops as f64;
            let allocs = allocs_per_op(ops, || drive(&on, clients, per));
            let base_median =
                median_ns(warmup.min(3), iters.min(20), || drive(&off, clients, per)) / ops as f64;
            let base_allocs = allocs_per_op(ops, || drive(&off, clients, per));
            ledger.push(LedgerEntry {
                name: format!("serve/simulate_coalesce/{arm}"),
                median_ns: median,
                allocs_per_op: allocs,
                baseline_median_ns: base_median,
                baseline_allocs_per_op: base_allocs,
            });
        }
    }

    // --- fault-layer inertness arm ----------------------------------------
    // Informational: the cost of the always-compiled injection points on an
    // inert plan. Workspace column runs a d=16 dot-product loop with a
    // panic/io/delay point triple per op; baseline runs the bare loop. An
    // inert point is one `Option` check, so `speedup` should read ~1.0 and
    // both columns allocate nothing — drift here means the fault layer grew
    // a hot-path cost it promised not to have (see `ees::fault`).
    {
        use ees::fault::FaultPlan;
        use ees::linalg::dot;

        let n = 16usize;
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        let mut r = Pcg64::new(4242);
        r.fill_normal(&mut a);
        r.fill_normal(&mut b);
        let plan = FaultPlan::inert();
        let reps = 4096usize;
        let median = median_ns(warmup, iters, || {
            for _ in 0..reps {
                plan.panic_point("serve.dispatch");
                let _ = plan.io_point("serve.tcp_read");
                plan.delay_point("risk.chunk");
                std::hint::black_box(dot(std::hint::black_box(&a), std::hint::black_box(&b)));
            }
        }) / reps as f64;
        let allocs = allocs_per_op(reps, || {
            for _ in 0..reps {
                plan.panic_point("serve.dispatch");
                let _ = plan.io_point("serve.tcp_read");
                plan.delay_point("risk.chunk");
                std::hint::black_box(dot(&a, &b));
            }
        });
        let base_median = median_ns(warmup, iters, || {
            for _ in 0..reps {
                std::hint::black_box(dot(std::hint::black_box(&a), std::hint::black_box(&b)));
            }
        }) / reps as f64;
        let base_allocs = allocs_per_op(reps, || {
            for _ in 0..reps {
                std::hint::black_box(dot(&a, &b));
            }
        });
        ledger.push(LedgerEntry {
            name: "fault/inert_points_dot/d16".into(),
            median_ns: median,
            allocs_per_op: allocs,
            baseline_median_ns: base_median,
            baseline_allocs_per_op: base_allocs,
        });
    }

    // --- feature-gated SIMD kernel arms ----------------------------------
    // The "workspace" column runs with the SIMD knob ON, the baseline
    // column with it OFF, so `speedup` reads directly as the SIMD win over
    // the scalar reference kernels on identical inputs.
    #[cfg(feature = "simd")]
    {
        use ees::linalg::{dot, matmul_lanes, set_simd};

        // Plain dot at the hot vector-field width (d = 16) and at d = 64.
        for n in [16usize, 64] {
            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            let mut r = Pcg64::new(200 + n as u64);
            r.fill_normal(&mut a);
            r.fill_normal(&mut b);
            let reps = 4096usize;
            set_simd(true);
            let median = median_ns(warmup, iters, || {
                for _ in 0..reps {
                    std::hint::black_box(dot(std::hint::black_box(&a), std::hint::black_box(&b)));
                }
            }) / reps as f64;
            let allocs = allocs_per_op(reps, || {
                for _ in 0..reps {
                    std::hint::black_box(dot(&a, &b));
                }
            });
            set_simd(false);
            let base_median = median_ns(warmup, iters, || {
                for _ in 0..reps {
                    std::hint::black_box(dot(std::hint::black_box(&a), std::hint::black_box(&b)));
                }
            }) / reps as f64;
            let base_allocs = allocs_per_op(reps, || {
                for _ in 0..reps {
                    std::hint::black_box(dot(&a, &b));
                }
            });
            ledger.push(LedgerEntry {
                name: format!("simd_dot/d{n}"),
                median_ns: median,
                allocs_per_op: allocs,
                baseline_median_ns: base_median,
                baseline_allocs_per_op: base_allocs,
            });
        }

        // The lane-major GEMM the group step runs on: 16x16 against an
        // 8-lane SoA block (the acceptance shape, d = 16, L = 8).
        {
            let (m, k, lanes) = (16usize, 16usize, 8usize);
            let mut a = vec![0.0; m * k];
            let mut x = vec![0.0; k * lanes];
            let mut out = vec![0.0; m * lanes];
            let mut r = Pcg64::new(77);
            r.fill_normal(&mut a);
            r.fill_normal(&mut x);
            let reps = 512usize;
            set_simd(true);
            let median = median_ns(warmup, iters, || {
                for _ in 0..reps {
                    matmul_lanes(&a, &x, &mut out, m, k, lanes);
                    std::hint::black_box(&out);
                }
            }) / reps as f64;
            let allocs = allocs_per_op(reps, || {
                for _ in 0..reps {
                    matmul_lanes(&a, &x, &mut out, m, k, lanes);
                }
            });
            set_simd(false);
            let base_median = median_ns(warmup, iters, || {
                for _ in 0..reps {
                    matmul_lanes(&a, &x, &mut out, m, k, lanes);
                    std::hint::black_box(&out);
                }
            }) / reps as f64;
            let base_allocs = allocs_per_op(reps, || {
                for _ in 0..reps {
                    matmul_lanes(&a, &x, &mut out, m, k, lanes);
                }
            });
            ledger.push(LedgerEntry {
                name: "simd_matmul_lanes/d16_l8".into(),
                median_ns: median,
                allocs_per_op: allocs,
                baseline_median_ns: base_median,
                baseline_allocs_per_op: base_allocs,
            });
        }

        // End-to-end: the full lane-blocked batch gradient with the SIMD
        // kernels dispatched vs the same lane engine on scalar kernels —
        // what EES_SIMD=1 actually buys a training epoch.
        {
            use ees::coordinator::{batch_grad_euclidean_pool_lanes, sample_paths_par};
            use ees::losses::MomentMatch;
            use ees::memory::WorkspacePool;
            use ees::nn::neural_sde::NeuralSde;
            let (dim, lanes) = (16usize, 8usize);
            let model = NeuralSde::lsde(dim, 32, 2, false, &mut Pcg64::new(3));
            let (batch, bsteps) = (16usize, 50usize);
            let mut brng = Pcg64::new(13);
            let y0s: Vec<Vec<f64>> = (0..batch).map(|_| vec![0.1; dim]).collect();
            let paths = sample_paths_par(&mut brng, batch, dim, bsteps, 0.02, 1);
            let obs = vec![bsteps];
            let loss = MomentMatch {
                target_mean: vec![0.0; dim],
                target_m2: vec![1.0; dim],
            };
            let st = LowStorageStepper::ees25();
            let pool = WorkspacePool::new();
            let ops = batch * bsteps;
            let run = || {
                let out = batch_grad_euclidean_pool_lanes(
                    &st,
                    AdjointMethod::Reversible,
                    &model,
                    &y0s,
                    &paths,
                    &obs,
                    &loss,
                    1,
                    &pool,
                    lanes,
                );
                std::hint::black_box(&out);
            };
            set_simd(true);
            let median = median_ns(warmup, iters, run) / ops as f64;
            let allocs = allocs_per_op(ops, run);
            set_simd(false);
            let base_median = median_ns(warmup, iters, run) / ops as f64;
            let base_allocs = allocs_per_op(ops, run);
            ledger.push(LedgerEntry {
                name: "batch_grad_lanes_simd/b16_s50_d16".into(),
                median_ns: median,
                allocs_per_op: allocs,
                baseline_median_ns: base_median,
                baseline_allocs_per_op: base_allocs,
            });
        }
    }

    println!("{}", ledger.render_table());
    let json = ledger.to_json();

    // Perf-regression gate (`--check`): compare this run's within-run
    // speedups (workspace vs baseline arm, same machine, same process)
    // against the COMMITTED BENCH_hotpath.json (read before any `--update`
    // rewrite) — absolute medians would gate on CI hardware variance. The
    // gate only arms against a measured baseline — an authoring-container
    // estimate would gate on fiction — and the lane acceptance arm must
    // hold its >= 1.5x win over per-sample stepping.
    let mut failures: Vec<String> = Vec::new();
    if check {
        match std::fs::read_to_string("BENCH_hotpath.json")
            .ok()
            .as_deref()
            .and_then(ees::bench::ledger::parse_baseline)
        {
            Some(base) if base.is_measured() => {
                failures.extend(ledger.regressions_vs(&base, 0.25));
            }
            Some(base) => println!(
                "check: committed baseline provenance is '{}' — regression gate \
                 arms once a measured ledger is committed",
                base.provenance
            ),
            None => println!("check: no parseable committed BENCH_hotpath.json — gate skipped"),
        }
        for gated in ["batch_grad_lanes/b16_s50_d16", "batch_grad_lanes/manifold"] {
            if let Some(e) = ledger.entries.iter().find(|e| e.name == gated) {
                if e.speedup() < 1.5 {
                    failures.push(format!(
                        "{gated}: lane speedup {:.2}x < required 1.5x",
                        e.speedup()
                    ));
                }
            }
        }
        // Serving coalescing acceptance arm: >= 2x over solo per-request
        // dispatch at 8 concurrent clients. Full mode only — quick mode's
        // short bursts leave the batch-formation window under-fed, which
        // understates the coalescing win and would fail on noise.
        if full {
            let gated = "serve/simulate_coalesce/c8_p1";
            if let Some(e) = ledger.entries.iter().find(|e| e.name == gated) {
                if e.speedup() < 2.0 {
                    failures.push(format!(
                        "{gated}: coalescing speedup {:.2}x < required 2.0x",
                        e.speedup()
                    ));
                }
            }
        }
        // SIMD acceptance arms: >= 1.3x over the scalar kernels, gated only
        // in full mode (quick mode's 15 iterations are too noisy to fail a
        // build on).
        #[cfg(feature = "simd")]
        {
            if full {
                for gated in ["simd_matmul_lanes/d16_l8", "batch_grad_lanes_simd/b16_s50_d16"] {
                    if let Some(e) = ledger.entries.iter().find(|e| e.name == gated) {
                        if e.speedup() < 1.3 {
                            failures.push(format!(
                                "{gated}: simd speedup {:.2}x < required 1.3x",
                                e.speedup()
                            ));
                        }
                    }
                }
            }
        }
    }

    if update {
        std::fs::write("BENCH_hotpath.json", &json).expect("write BENCH_hotpath.json");
        println!("wrote BENCH_hotpath.json");
    } else {
        println!("{json}");
    }
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("PERF REGRESSION: {f}");
        }
        std::process::exit(1);
    }
}
