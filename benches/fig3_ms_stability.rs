//! Bench: Figure 3 — mean-square stability cross-sections.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    println!("{}", ees::experiments::fig3::run(if full { 20000 } else { 2000 }));
}
