//! Bench: design-choice ablations (x-parameter, 2N realisation, MCF λ).
fn main() {
    println!("{}", ees::experiments::ablations::run());
}
