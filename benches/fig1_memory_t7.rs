//! Bench: Figure 1 / Table 15 — memory on T^7 vs step count.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let steps: Vec<usize> = if full {
        vec![5, 10, 20, 50, 100, 200, 400, 800, 2000, 5000, 10000]
    } else {
        vec![5, 20, 100, 400]
    };
    let batch = if full { 64 } else { 4 };
    println!("{}", ees::experiments::fig1::run(batch, &steps));
}
