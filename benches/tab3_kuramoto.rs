//! Bench: Table 3 / Figure 5b / Table 13 — stochastic Kuramoto on T T^N.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { ees::experiments::Scale::Full } else { ees::experiments::Scale::Smoke };
    println!("{}", ees::experiments::tab3::run(scale));
    let (n, steps): (usize, Vec<usize>) = if std::env::args().any(|a| a == "--full") {
        (1000, vec![50, 100, 200, 500, 1000, 2000, 5000])
    } else {
        (16, vec![50, 100, 200, 500])
    };
    println!("{}", ees::experiments::tab3::run_memory(n, &steps));
}
