//! Bench: Table 7 / Figures 10-11 — stiff GBM.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { ees::experiments::Scale::Full } else { ees::experiments::Scale::Smoke };
    println!("{}", ees::experiments::tab7::run(scale));
}
