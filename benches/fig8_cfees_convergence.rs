//! Bench: Figure 8 — CF-EES convergence on the SO(3) RDE.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { ees::experiments::Scale::Full } else { ees::experiments::Scale::Smoke };
    println!("{}", ees::experiments::fig8::run(scale));
}
