//! Bench: Table 12 — adjoint gradient fidelity.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { ees::experiments::Scale::Full } else { ees::experiments::Scale::Smoke };
    println!("{}", ees::experiments::tab12::run(scale));
}
