//! Bench: Table 8 — the remaining stochastic-volatility models.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { ees::experiments::Scale::Full } else { ees::experiments::Scale::Smoke };
    use ees::models::stochvol::VolModel;
    let models: Vec<VolModel> = VolModel::all()
        .into_iter()
        .filter(|m| *m != VolModel::RoughBergomi)
        .collect();
    let models = if std::env::args().any(|a| a == "--full") { models } else { models[..2].to_vec() };
    println!("{}", ees::experiments::tab2::run(scale, &models));
}
