//! Bench: Table 9 / Figure 13 — Langevin MD proxy.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { ees::experiments::Scale::Full } else { ees::experiments::Scale::Smoke };
    println!("{}", ees::experiments::tab9::run(scale));
}
