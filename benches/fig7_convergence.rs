//! Bench: Figure 7 — EES convergence under fBm drivers.
fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let scale = if full { ees::experiments::Scale::Full } else { ees::experiments::Scale::Smoke };
    println!("{}", ees::experiments::fig7::run(scale));
}
